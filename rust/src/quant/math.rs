//! Level/bit arithmetic and the paper's communication-cost model.

/// Wire bits for quantization level `s` (codes in `0..=s`):
/// `bit = ceil(log2(s + 1))` — paper §IV and the `C_s` model.
#[inline]
pub fn bits_for_level(s: u32) -> u32 {
    crate::wire::bitpack::width_for_level(s)
}

/// Largest level representable in `bits` wire bits: `2^bits - 1`.
#[inline]
pub fn max_level_for_bits(bits: u32) -> u32 {
    debug_assert!(bits >= 1 && bits <= 32);
    if bits >= 32 {
        u32::MAX
    } else {
        (1u32 << bits) - 1
    }
}

/// The paper's Eq. 10: `bit_m = ceil(log2(range_m / resolution))`, clamped
/// to `[1, max_bits]`.  Degenerate ranges (0, subnormal, non-finite) fall
/// back to 1 bit — the update is constant, one bin suffices.
pub fn feddq_bits(range: f32, resolution: f32, max_bits: u32) -> u32 {
    if range.is_infinite() && range > 0.0 {
        return max_bits; // defensive: a blown-up update gets max precision
    }
    if !range.is_finite() || range <= 0.0 {
        return 1;
    }
    let ratio = range / resolution;
    if ratio <= 1.0 {
        return 1;
    }
    let bits = (ratio.log2()).ceil() as u32;
    bits.clamp(1, max_bits)
}

/// The exact whole-update range from per-segment (min, range) pairs:
/// `max_l(min_l + range_l) - min_l(min_l)` — Eq. 10's range when one
/// bit-width covers the entire update.  A positive-infinite segment
/// range propagates (a blown-up update keeps max precision downstream);
/// NaN segments are skipped; negative ranges count as width-0 at their
/// min.  With no usable segment the range is 0 (degenerate → 1 bit).
pub fn whole_range(mins: &[f32], ranges: &[f32]) -> f32 {
    debug_assert_eq!(mins.len(), ranges.len());
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for (&mn, &r) in mins.iter().zip(ranges) {
        if r.is_infinite() && r > 0.0 {
            return f32::INFINITY;
        }
        if !mn.is_finite() || r.is_nan() {
            continue;
        }
        let r = r.max(0.0);
        lo = lo.min(mn);
        hi = hi.max(mn + r);
    }
    if lo.is_finite() && hi.is_finite() && hi > lo {
        hi - lo
    } else {
        0.0
    }
}

/// Uplink cost in bits of one client update under per-segment levels:
/// `sum_l d_l * bits(s_l) + header_bits_per_segment * L` plus the fixed
/// message envelope.  Matches what the wire encoder actually produces
/// (asserted by integration tests).
pub fn update_payload_bits(seg_sizes: &[usize], bits: &[u32]) -> u64 {
    debug_assert_eq!(seg_sizes.len(), bits.len());
    seg_sizes
        .iter()
        .zip(bits)
        .map(|(&d, &b)| d as u64 * b as u64)
        .sum()
}

/// Per-segment header overhead on the wire:
/// bits(u8) + level(u16) + min(f32) + step(f32) — see wire::messages.
pub const SEGMENT_HEADER_BITS: u64 = 8 + 16 + 32 + 32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_level_inverse() {
        for bits in 1..=16u32 {
            let s = max_level_for_bits(bits);
            assert_eq!(bits_for_level(s), bits);
            assert_eq!(bits_for_level(s + 1), bits + 1);
        }
    }

    #[test]
    fn feddq_bits_descends_with_range() {
        let res = 0.005;
        let b_wide = feddq_bits(1.0, res, 16); // range 1.0 => ~7.6 -> 8 bits
        let b_mid = feddq_bits(0.1, res, 16);
        let b_narrow = feddq_bits(0.01, res, 16);
        assert!(b_wide > b_mid && b_mid > b_narrow, "{b_wide} {b_mid} {b_narrow}");
        assert_eq!(feddq_bits(1.0, 0.005, 16), 8); // log2(200) = 7.64 -> 8
    }

    #[test]
    fn feddq_bits_degenerate_ranges() {
        assert_eq!(feddq_bits(0.0, 0.005, 16), 1);
        assert_eq!(feddq_bits(-1.0, 0.005, 16), 1);
        assert_eq!(feddq_bits(f32::NAN, 0.005, 16), 1);
        assert_eq!(feddq_bits(f32::INFINITY, 0.005, 16), 16); // clamped
        assert_eq!(feddq_bits(0.004, 0.005, 16), 1); // below resolution
    }

    #[test]
    fn payload_bits_sums_segments() {
        assert_eq!(update_payload_bits(&[100, 50], &[8, 4]), 1000);
        assert_eq!(update_payload_bits(&[], &[]), 0);
    }

    #[test]
    fn whole_range_is_the_global_envelope() {
        // Extremes in different segments: [-1, -0.5] and [0.5, 1.0] span
        // 2.0 even though no single segment range exceeds 0.5.
        let r = whole_range(&[-1.0, 0.5], &[0.5, 0.5]);
        assert!((r - 2.0).abs() < 1e-6, "{r}");
        // When one segment holds both extremes, envelope == max range.
        let r = whole_range(&[-1.0, -0.1], &[2.0, 0.2]);
        assert!((r - 2.0).abs() < 1e-6, "{r}");
        // Degenerate inputs collapse instead of going NaN/negative.
        assert_eq!(whole_range(&[], &[]), 0.0);
        assert_eq!(whole_range(&[0.3], &[0.0]), 0.0);
        assert_eq!(whole_range(&[f32::NAN], &[1.0]), 0.0);
        assert_eq!(whole_range(&[0.0, f32::NAN], &[1.0, f32::NAN]), 1.0);
        assert_eq!(whole_range(&[0.0], &[f32::INFINITY]), f32::INFINITY);
        // Negative range counts as a point at its min.
        let r = whole_range(&[-2.0, 0.0], &[-1.0, 1.0]);
        assert!((r - 3.0).abs() < 1e-6, "{r}");
    }

    #[test]
    fn prop_feddq_bits_bounded_for_any_range() {
        use crate::util::prop::{check, Gen};
        check("feddq-bits-bounded", 200, |g: &mut Gen| {
            let range = match g.int(0, 6) {
                0 => 0.0,
                1 => 1.0e-40,           // subnormal
                2 => f32::MIN_POSITIVE, // smallest normal
                3 => f32::INFINITY,
                4 => f32::NAN,
                5 => -g.f32(0.0, 10.0),
                _ => g.f32_wide(),
            };
            let max_bits = g.int(1, 16) as u32;
            let bits = feddq_bits(range, 0.005, max_bits);
            if !(1..=max_bits).contains(&bits) {
                return Err(format!("range {range}: bits {bits} outside [1, {max_bits}]"));
            }
            // Degenerate ranges must collapse to the 1-bit floor
            // (positive infinity instead pins to max precision).
            if (range.is_nan() || range <= 0.0) && bits != 1 {
                return Err(format!("degenerate range {range} got {bits} bits"));
            }
            Ok(())
        });
    }
}
