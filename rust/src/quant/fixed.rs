//! Fixed-bit and fp32 baselines (QSGD-style static quantization).

use super::{math, Decision, PolicyInputs, QuantPolicy};

/// Constant bit-width for every segment, every round.
pub struct Fixed {
    level: u32,
}

impl Fixed {
    /// Policy transmitting every segment at `bits` wire bits (1..=16).
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "fixed bits in 1..=16");
        Fixed {
            level: math::max_level_for_bits(bits),
        }
    }
}

impl QuantPolicy for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn decide(&mut self, inputs: &PolicyInputs) -> Decision {
        Decision {
            levels: Some(vec![self.level; inputs.ranges.len()]),
        }
    }
}

/// No quantization: raw f32 uplink (the FedAvg baseline).
pub struct Fp32;

impl QuantPolicy for Fp32 {
    fn name(&self) -> &'static str {
        "fp32"
    }

    fn decide(&mut self, _inputs: &PolicyInputs) -> Decision {
        Decision::fp32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(ranges: &'static [f32]) -> PolicyInputs<'static> {
        PolicyInputs {
            round: 0,
            client_id: 0,
            ranges,
            mins: &[],
            initial_loss: None,
            prev_loss: None,
        }
    }

    #[test]
    fn fixed_levels() {
        let mut p = Fixed::new(8);
        let d = p.decide(&inputs(&[0.1, 100.0]));
        assert_eq!(d.bits(0), 8);
        assert_eq!(d.levels.unwrap(), vec![255, 255]);
    }

    #[test]
    fn fp32_is_passthrough() {
        let mut p = Fp32;
        let d = p.decide(&inputs(&[0.5]));
        assert_eq!(d, Decision::fp32());
        assert_eq!(d.bits(0), 32);
    }

    #[test]
    fn prop_fixed_policy_ignores_degenerate_ranges() {
        use crate::quant::math;
        use crate::util::prop::{check, Gen};
        // The fixed policy's level must be constant and valid whatever
        // degenerate ranges a frozen layer reports — the quantizer plan
        // (codec::QuantPlan) handles the per-segment collapse.
        check("fixed-degenerate-ranges", 100, |g: &mut Gen| {
            let bits = g.int(1, 16) as u32;
            let l = g.size(1, 6);
            let ranges: Vec<f32> = g.vec_of(l, |g| match g.int(0, 4) {
                0 => 0.0,
                1 => 1.0e-40, // subnormal
                2 => f32::INFINITY,
                3 => f32::NAN,
                _ => g.f32_wide(),
            });
            let mut p = Fixed::new(bits);
            let d = p.decide(&PolicyInputs {
                round: 0,
                client_id: 0,
                ranges: &ranges,
                mins: &ranges, // arbitrary; fixed ignores both
                initial_loss: None,
                prev_loss: None,
            });
            let levels = d.levels.ok_or("fixed must quantize")?;
            let want = math::max_level_for_bits(bits);
            if levels.len() != l || levels.iter().any(|&s| s != want) {
                return Err(format!("bits {bits}: levels {levels:?} != {want}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic]
    fn rejects_zero_bits() {
        Fixed::new(0);
    }
}
