//! Quantization policies — the paper's contribution lives here.
//!
//! A [`QuantPolicy`] decides, per client per round, the quantization level
//! `s` for every parameter segment, given the observed update ranges and
//! the global training-loss trajectory:
//!
//! * [`feddq::FedDq`] — the paper: `bit = ceil(log2(range / resolution))`
//!   (Eq. 10), which *descends* as the model converges.
//! * [`adaquantfl::AdaQuantFl`] — the prior SOTA baseline:
//!   `s_m = s_0 * sqrt(F_0 / F_m)` from the global loss, which *ascends*.
//! * [`fixed::Fixed`] / [`fixed::Fp32`] — fixed-bit and no-quantization
//!   baselines.

pub mod adaquantfl;
pub mod budget;
pub mod feddq;
pub mod fixed;
pub mod math;

use crate::Result;

/// Everything a policy may condition on at round `m` for one client.
#[derive(Clone, Debug)]
pub struct PolicyInputs<'a> {
    /// Round index (0-based).
    pub round: u32,
    /// The deciding client's id.
    pub client_id: u32,
    /// Per-segment update ranges observed *this* round (max - min).
    pub ranges: &'a [f32],
    /// Per-segment update minima observed *this* round.  Together with
    /// `ranges` this is the exact per-segment envelope, so whole-model
    /// policies (FedDQ's Eq. 10 as written) can compute the true global
    /// update range `max_l(min_l + range_l) - min_l(min_l)` instead of
    /// approximating it with the largest segment range.
    pub mins: &'a [f32],
    /// Global average training loss of round 0 (set after the first
    /// round's updates arrive; policies must handle `None` at m=0).
    pub initial_loss: Option<f32>,
    /// Global average training loss of the previous round.
    pub prev_loss: Option<f32>,
}

/// Per-segment quantization decision.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// Quantization level `s` per segment (codes in 0..=s).  `None`
    /// means fp32 passthrough for every segment.
    pub levels: Option<Vec<u32>>,
}

impl Decision {
    /// The no-quantization decision: every segment ships raw f32.
    pub fn fp32() -> Self {
        Decision { levels: None }
    }

    /// Wire bits per element for segment `l` under this decision.
    pub fn bits(&self, l: usize) -> u32 {
        match &self.levels {
            None => 32,
            Some(ls) => math::bits_for_level(ls[l]),
        }
    }
}

/// A quantization-level scheduling policy.
pub trait QuantPolicy: Send {
    /// Short policy identifier (reports and labels).
    fn name(&self) -> &'static str;
    /// Choose quantization levels for one client's update.
    fn decide(&mut self, inputs: &PolicyInputs) -> Decision;
}

/// Config-level policy selection (parsed from CLI / config JSON).
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyConfig {
    /// The paper's descending policy (Eq. 10), per-segment ranges;
    /// `resolution` is the accuracy/volume trade-off knob.
    FedDq {
        /// Target quantization resolution (paper §IV: 0.005).
        resolution: f32,
    },
    /// FedDQ with a single bit-width from the whole-model range
    /// (Eq. 10 as literally written; the per-segment default is finer).
    FedDqWhole {
        /// Target quantization resolution (paper §IV: 0.005).
        resolution: f32,
    },
    /// `s0`: initial quantization level (paper [12] uses small s0, e.g. 2).
    AdaQuantFl {
        /// Initial quantization level `s_0`.
        s0: u32,
    },
    /// Constant bit-width baseline.
    Fixed {
        /// Wire bits per code, 1..=16.
        bits: u32,
    },
    /// No quantization: raw f32 uplink (FedAvg baseline).
    Fp32,
}

impl PolicyConfig {
    /// Parse `feddq[:res]`, `adaquantfl[:s0]`, `fixed:<bits>`, `fp32`.
    pub fn parse(s: &str) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "feddq" => {
                let resolution = arg.map(str::parse).transpose()?.unwrap_or(0.005);
                anyhow::ensure!(resolution > 0.0, "resolution must be positive");
                Ok(PolicyConfig::FedDq { resolution })
            }
            "feddq-whole" => {
                let resolution = arg.map(str::parse).transpose()?.unwrap_or(0.005);
                anyhow::ensure!(resolution > 0.0, "resolution must be positive");
                Ok(PolicyConfig::FedDqWhole { resolution })
            }
            "adaquantfl" => {
                let s0 = arg.map(str::parse).transpose()?.unwrap_or(2);
                anyhow::ensure!(s0 >= 1, "s0 must be >= 1");
                Ok(PolicyConfig::AdaQuantFl { s0 })
            }
            "fixed" => {
                let bits: u32 = arg
                    .ok_or_else(|| anyhow::anyhow!("fixed policy needs :<bits>"))?
                    .parse()?;
                anyhow::ensure!((1..=16).contains(&bits), "fixed bits in 1..=16");
                Ok(PolicyConfig::Fixed { bits })
            }
            "fp32" => Ok(PolicyConfig::Fp32),
            _ => anyhow::bail!("unknown policy {s:?}"),
        }
    }

    /// Instantiate the configured policy.
    pub fn build(&self) -> Box<dyn QuantPolicy> {
        match self {
            PolicyConfig::FedDq { resolution } => {
                Box::new(feddq::FedDq::new(*resolution))
            }
            PolicyConfig::FedDqWhole { resolution } => Box::new(
                feddq::FedDq::new(*resolution)
                    .with_granularity(feddq::Granularity::Whole),
            ),
            PolicyConfig::AdaQuantFl { s0 } => {
                Box::new(adaquantfl::AdaQuantFl::new(*s0))
            }
            PolicyConfig::Fixed { bits } => Box::new(fixed::Fixed::new(*bits)),
            PolicyConfig::Fp32 => Box::new(fixed::Fp32),
        }
    }

    /// Canonical string form, parseable by [`Self::parse`].
    pub fn label(&self) -> String {
        match self {
            PolicyConfig::FedDq { resolution } => format!("feddq:{resolution}"),
            PolicyConfig::FedDqWhole { resolution } => format!("feddq-whole:{resolution}"),
            PolicyConfig::AdaQuantFl { s0 } => format!("adaquantfl:{s0}"),
            PolicyConfig::Fixed { bits } => format!("fixed:{bits}"),
            PolicyConfig::Fp32 => "fp32".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_policies() {
        assert_eq!(
            PolicyConfig::parse("feddq").unwrap(),
            PolicyConfig::FedDq { resolution: 0.005 }
        );
        assert_eq!(
            PolicyConfig::parse("feddq:0.01").unwrap(),
            PolicyConfig::FedDq { resolution: 0.01 }
        );
        assert_eq!(
            PolicyConfig::parse("adaquantfl:4").unwrap(),
            PolicyConfig::AdaQuantFl { s0: 4 }
        );
        assert_eq!(
            PolicyConfig::parse("fixed:8").unwrap(),
            PolicyConfig::Fixed { bits: 8 }
        );
        assert_eq!(PolicyConfig::parse("fp32").unwrap(), PolicyConfig::Fp32);
        assert!(PolicyConfig::parse("nope").is_err());
        assert!(PolicyConfig::parse("fixed").is_err());
        assert!(PolicyConfig::parse("fixed:40").is_err());
        assert!(PolicyConfig::parse("feddq:-1").is_err());
    }

    #[test]
    fn label_roundtrip() {
        for s in ["feddq:0.005", "feddq-whole:0.01", "adaquantfl:2", "fixed:8", "fp32"] {
            let p = PolicyConfig::parse(s).unwrap();
            assert_eq!(PolicyConfig::parse(&p.label()).unwrap(), p);
        }
    }
}
