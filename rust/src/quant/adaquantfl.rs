//! AdaQuantFL (Jhunjhunwala et al., ICASSP 2021) — the ascending adaptive
//! baseline the paper compares against.
//!
//! The quantization level at round `m` is derived from the global training
//! loss trajectory:
//!
//! ```text
//! s_m = s_0 * sqrt( F(X_0) / F(X_m) )
//! ```
//!
//! Training loss decreases with training, so `s_m` (and the bit-width)
//! *increases* — the "ascending-trend" scheme whose inefficiency FedDQ's
//! analysis exposes.  The level is global (same for every client and
//! segment), matching the reference algorithm.

use super::{math, Decision, PolicyInputs, QuantPolicy};

/// The ascending AdaQuantFL baseline (see module docs).
pub struct AdaQuantFl {
    s0: u32,
    max_bits: u32,
}

impl AdaQuantFl {
    /// Policy starting at level `s_0` (clamped to >= 1), 16-bit ceiling.
    pub fn new(s0: u32) -> Self {
        AdaQuantFl { s0: s0.max(1), max_bits: 16 }
    }

    /// Builder: cap the bit-width at `b` (1..=16).
    pub fn with_max_bits(mut self, b: u32) -> Self {
        assert!((1..=16).contains(&b));
        self.max_bits = b;
        self
    }

    fn level(&self, inputs: &PolicyInputs) -> u32 {
        let (Some(f0), Some(fm)) = (inputs.initial_loss, inputs.prev_loss) else {
            // Round 0: no loss observed yet; the reference starts at s_0.
            return self.s0;
        };
        if !(f0.is_finite() && fm.is_finite()) || f0 <= 0.0 || fm <= 0.0 {
            return self.s0;
        }
        let s = (self.s0 as f64 * (f0 as f64 / fm as f64).sqrt()).round();
        let cap = math::max_level_for_bits(self.max_bits) as f64;
        s.clamp(1.0, cap) as u32
    }
}

impl QuantPolicy for AdaQuantFl {
    fn name(&self) -> &'static str {
        "adaquantfl"
    }

    fn decide(&mut self, inputs: &PolicyInputs) -> Decision {
        let s = self.level(inputs);
        Decision {
            levels: Some(vec![s; inputs.ranges.len()]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(f0: Option<f32>, fm: Option<f32>) -> PolicyInputs<'static> {
        PolicyInputs {
            round: 1,
            client_id: 0,
            ranges: &[0.1, 0.2],
            mins: &[0.0, 0.0],
            initial_loss: f0,
            prev_loss: fm,
        }
    }

    #[test]
    fn starts_at_s0() {
        let mut p = AdaQuantFl::new(2);
        assert_eq!(p.decide(&inputs(None, None)).levels.unwrap(), vec![2, 2]);
    }

    #[test]
    fn ascends_as_loss_falls() {
        let mut p = AdaQuantFl::new(2);
        let s_early = p.decide(&inputs(Some(2.3), Some(2.3))).levels.unwrap()[0];
        let s_mid = p.decide(&inputs(Some(2.3), Some(1.0))).levels.unwrap()[0];
        let s_late = p.decide(&inputs(Some(2.3), Some(0.1))).levels.unwrap()[0];
        assert!(s_early <= s_mid && s_mid < s_late, "{s_early} {s_mid} {s_late}");
        assert_eq!(s_early, 2);
        assert_eq!(s_late, (2.0f64 * (2.3f64 / 0.1).sqrt()).round() as u32);
    }

    #[test]
    fn clamps_at_max_bits() {
        let mut p = AdaQuantFl::new(2).with_max_bits(4);
        let s = p.decide(&inputs(Some(100.0), Some(1e-6))).levels.unwrap()[0];
        assert_eq!(s, 15);
    }

    #[test]
    fn degenerate_losses_fall_back_to_s0() {
        let mut p = AdaQuantFl::new(3);
        for (f0, fm) in [
            (Some(0.0), Some(1.0)),
            (Some(1.0), Some(0.0)),
            (Some(f32::NAN), Some(1.0)),
            (Some(1.0), Some(f32::NEG_INFINITY)),
        ] {
            assert_eq!(p.decide(&inputs(f0, fm)).levels.unwrap()[0], 3);
        }
    }
}
