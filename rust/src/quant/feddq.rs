//! FedDQ — the paper's descending quantization policy (Eq. 10).
//!
//! The optimal quantization level is proportional to the range of the
//! model update (Eq. 7), so each round each client sets, per segment,
//!
//! ```text
//! bit_l = ceil( log2( range_l / resolution ) )
//! s_l   = 2^bit_l - 1
//! ```
//!
//! Since the update range shrinks as training converges (Fig. 1b), the
//! bit-width *descends* — the opposite of AdaQuantFL.  `resolution` is
//! the paper's accuracy/volume trade-off hyper-parameter (0.005 in §IV).
//!
//! Granularity: the paper computes one range per client update; Fig. 1b
//! plots per-layer ranges.  We support both — per-segment (default, finer)
//! and whole-model (`granularity = Whole`, ablation bench) where a single
//! bit-width derived from the *global* update range applies to every
//! segment.

use super::{math, Decision, PolicyInputs, QuantPolicy};

/// Range granularity FedDQ derives its bit-widths from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One bit-width per parameter segment (layer).
    PerSegment,
    /// One bit-width for the entire update (the paper's Eq. 10 as written).
    Whole,
}

/// The paper's descending-quantization policy (see module docs).
pub struct FedDq {
    resolution: f32,
    max_bits: u32,
    granularity: Granularity,
}

impl FedDq {
    /// Policy at `resolution` (paper §IV: 0.005), per-segment
    /// granularity, 16-bit ceiling.
    pub fn new(resolution: f32) -> Self {
        FedDq {
            resolution,
            max_bits: 16,
            granularity: Granularity::PerSegment,
        }
    }

    /// Builder: switch the range granularity.
    pub fn with_granularity(mut self, g: Granularity) -> Self {
        self.granularity = g;
        self
    }

    /// Builder: cap the bit-width at `b` (1..=16).
    pub fn with_max_bits(mut self, b: u32) -> Self {
        assert!((1..=16).contains(&b));
        self.max_bits = b;
        self
    }
}

impl QuantPolicy for FedDq {
    fn name(&self) -> &'static str {
        "feddq"
    }

    fn decide(&mut self, inputs: &PolicyInputs) -> Decision {
        let levels = match self.granularity {
            Granularity::PerSegment => inputs
                .ranges
                .iter()
                .map(|&r| {
                    let bits = math::feddq_bits(r, self.resolution, self.max_bits);
                    math::max_level_for_bits(bits)
                })
                .collect(),
            Granularity::Whole => {
                // Range of the whole update: the exact global envelope
                // over the per-segment (min, range) pairs.  The old
                // max-segment-range approximation under-sized the range
                // whenever segment extremes didn't coincide (e.g. one
                // segment all-negative, another all-positive).
                let r = math::whole_range(inputs.mins, inputs.ranges);
                let bits = math::feddq_bits(r, self.resolution, self.max_bits);
                let s = math::max_level_for_bits(bits);
                vec![s; inputs.ranges.len()]
            }
        };
        Decision { levels: Some(levels) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs<'a>(mins: &'a [f32], ranges: &'a [f32]) -> PolicyInputs<'a> {
        PolicyInputs {
            round: 0,
            client_id: 0,
            ranges,
            mins,
            initial_loss: None,
            prev_loss: None,
        }
    }

    #[test]
    fn per_segment_levels_follow_ranges() {
        let mut p = FedDq::new(0.005);
        let d = p.decide(&inputs(&[0.0, 0.0, 0.0], &[1.0, 0.01, 0.0]));
        let levels = d.levels.unwrap();
        assert_eq!(levels.len(), 3);
        assert_eq!(math::bits_for_level(levels[0]), 8);
        assert_eq!(math::bits_for_level(levels[1]), 1);
        assert_eq!(math::bits_for_level(levels[2]), 1);
    }

    #[test]
    fn descends_as_ranges_shrink() {
        let mut p = FedDq::new(0.005);
        let early: u32 = p
            .decide(&inputs(&[0.0, 0.0], &[0.8, 0.6]))
            .levels
            .unwrap()
            .iter()
            .map(|&s| math::bits_for_level(s))
            .sum();
        let late: u32 = p
            .decide(&inputs(&[0.0, 0.0], &[0.05, 0.02]))
            .levels
            .unwrap()
            .iter()
            .map(|&s| math::bits_for_level(s))
            .sum();
        assert!(late < early, "late {late} >= early {early}");
    }

    #[test]
    fn whole_granularity_is_uniform() {
        let mut p = FedDq::new(0.005).with_granularity(Granularity::Whole);
        let d = p.decide(&inputs(&[0.0, 0.0, 0.0], &[1.0, 0.01, 0.3]));
        let levels = d.levels.unwrap();
        assert!(levels.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(math::bits_for_level(levels[0]), 8); // envelope = max range here
    }

    #[test]
    fn whole_granularity_uses_the_true_envelope_across_segments() {
        // Segment extremes straddle two segments: one spans [-1, -0.5],
        // the other [0.5, 1.0].  The whole-update range is 2.0, but the
        // old max-segment-range approximation saw only 0.5 — a 2-bit
        // under-sizing of Eq. 10.
        let mut p = FedDq::new(0.005).with_granularity(Granularity::Whole);
        let d = p.decide(&inputs(&[-1.0, 0.5], &[0.5, 0.5]));
        let bits = math::bits_for_level(d.levels.unwrap()[0]);
        // ceil(log2(2.0 / 0.005)) = ceil(8.64) = 9, not ceil(log2(100)) = 7.
        assert_eq!(bits, 9);
        // Sanity: when one segment holds both extremes the envelope
        // degenerates to the max segment range and nothing changes.
        let d = p.decide(&inputs(&[-1.0, -0.1], &[2.0, 0.2]));
        assert_eq!(math::bits_for_level(d.levels.unwrap()[0]), 9); // log2(400) = 8.6
    }

    #[test]
    fn max_bits_clamps() {
        let mut p = FedDq::new(1e-9).with_max_bits(4);
        let d = p.decide(&inputs(&[0.0], &[10.0]));
        assert_eq!(math::bits_for_level(d.levels.unwrap()[0]), 4);
    }

    #[test]
    fn prop_degenerate_ranges_never_break_the_policy() {
        use crate::util::prop::{check, Gen};
        // FedDQ (both granularities) must emit valid levels for every
        // degenerate (min, range) combination a frozen or blown-up
        // layer can produce: zeros, subnormals, infinities, NaNs.
        check("feddq-degenerate-ranges", 100, |g: &mut Gen| {
            let l = g.size(1, 6);
            let pick = |g: &mut Gen| match g.int(0, 5) {
                0 => 0.0,
                1 => 1.0e-40, // subnormal
                2 => f32::INFINITY,
                3 => f32::NAN,
                4 => -g.f32(0.0, 2.0),
                _ => g.f32_wide(),
            };
            let ranges: Vec<f32> = g.vec_of(l, pick);
            let mins: Vec<f32> = g.vec_of(l, pick);
            for granularity in [Granularity::PerSegment, Granularity::Whole] {
                let mut p = FedDq::new(0.005).with_granularity(granularity);
                let d = p.decide(&inputs(&mins, &ranges));
                let levels = d.levels.ok_or("feddq must always quantize")?;
                if levels.len() != l {
                    return Err(format!("{} levels for {l} segments", levels.len()));
                }
                for &s in &levels {
                    let bits = math::bits_for_level(s);
                    if s < 1 || !(1..=16).contains(&bits) {
                        return Err(format!(
                            "{granularity:?}: level {s} / bits {bits} out of range for ranges {ranges:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
