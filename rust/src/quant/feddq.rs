//! FedDQ — the paper's descending quantization policy (Eq. 10).
//!
//! The optimal quantization level is proportional to the range of the
//! model update (Eq. 7), so each round each client sets, per segment,
//!
//! ```text
//! bit_l = ceil( log2( range_l / resolution ) )
//! s_l   = 2^bit_l - 1
//! ```
//!
//! Since the update range shrinks as training converges (Fig. 1b), the
//! bit-width *descends* — the opposite of AdaQuantFL.  `resolution` is
//! the paper's accuracy/volume trade-off hyper-parameter (0.005 in §IV).
//!
//! Granularity: the paper computes one range per client update; Fig. 1b
//! plots per-layer ranges.  We support both — per-segment (default, finer)
//! and whole-model (`granularity = Whole`, ablation bench) where a single
//! bit-width derived from the *global* update range applies to every
//! segment.

use super::{math, Decision, PolicyInputs, QuantPolicy};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One bit-width per parameter segment (layer).
    PerSegment,
    /// One bit-width for the entire update (the paper's Eq. 10 as written).
    Whole,
}

pub struct FedDq {
    resolution: f32,
    max_bits: u32,
    granularity: Granularity,
}

impl FedDq {
    pub fn new(resolution: f32) -> Self {
        FedDq {
            resolution,
            max_bits: 16,
            granularity: Granularity::PerSegment,
        }
    }

    pub fn with_granularity(mut self, g: Granularity) -> Self {
        self.granularity = g;
        self
    }

    pub fn with_max_bits(mut self, b: u32) -> Self {
        assert!((1..=16).contains(&b));
        self.max_bits = b;
        self
    }
}

impl QuantPolicy for FedDq {
    fn name(&self) -> &'static str {
        "feddq"
    }

    fn decide(&mut self, inputs: &PolicyInputs) -> Decision {
        let levels = match self.granularity {
            Granularity::PerSegment => inputs
                .ranges
                .iter()
                .map(|&r| {
                    let bits = math::feddq_bits(r, self.resolution, self.max_bits);
                    math::max_level_for_bits(bits)
                })
                .collect(),
            Granularity::Whole => {
                // Range of the whole update = max over segments of the
                // segment ranges' envelope; we approximate with the max
                // segment range (exact when segments share the extremes).
                let r = inputs.ranges.iter().copied().fold(0.0f32, f32::max);
                let bits = math::feddq_bits(r, self.resolution, self.max_bits);
                let s = math::max_level_for_bits(bits);
                vec![s; inputs.ranges.len()]
            }
        };
        Decision { levels: Some(levels) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(ranges: &[f32]) -> PolicyInputs {
        PolicyInputs {
            round: 0,
            client_id: 0,
            ranges,
            initial_loss: None,
            prev_loss: None,
        }
    }

    #[test]
    fn per_segment_levels_follow_ranges() {
        let mut p = FedDq::new(0.005);
        let d = p.decide(&inputs(&[1.0, 0.01, 0.0]));
        let levels = d.levels.unwrap();
        assert_eq!(levels.len(), 3);
        assert_eq!(math::bits_for_level(levels[0]), 8);
        assert_eq!(math::bits_for_level(levels[1]), 1);
        assert_eq!(math::bits_for_level(levels[2]), 1);
    }

    #[test]
    fn descends_as_ranges_shrink() {
        let mut p = FedDq::new(0.005);
        let early: u32 = p
            .decide(&inputs(&[0.8, 0.6]))
            .levels
            .unwrap()
            .iter()
            .map(|&s| math::bits_for_level(s))
            .sum();
        let late: u32 = p
            .decide(&inputs(&[0.05, 0.02]))
            .levels
            .unwrap()
            .iter()
            .map(|&s| math::bits_for_level(s))
            .sum();
        assert!(late < early, "late {late} >= early {early}");
    }

    #[test]
    fn whole_granularity_is_uniform() {
        let mut p = FedDq::new(0.005).with_granularity(Granularity::Whole);
        let d = p.decide(&inputs(&[1.0, 0.01, 0.3]));
        let levels = d.levels.unwrap();
        assert!(levels.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(math::bits_for_level(levels[0]), 8); // driven by max range
    }

    #[test]
    fn max_bits_clamps() {
        let mut p = FedDq::new(1e-9).with_max_bits(4);
        let d = p.decide(&inputs(&[10.0]));
        assert_eq!(math::bits_for_level(d.levels.unwrap()[0]), 4);
    }
}
