//! Data pipeline: dataset sources, client sharding and round batching.
//!
//! Real Fashion-MNIST / CIFAR-10 files are loaded when present under
//! `data/` ([`idx`], [`cifar`]); otherwise procedurally generated
//! class-structured datasets at identical shapes stand in ([`synthetic`])
//! — see DESIGN.md §3 for why that substitution preserves the paper's
//! claims.  [`shard`] splits a dataset across clients (IID or
//! Dirichlet non-IID) and [`batch`] assembles the `tau x B` round batches
//! the AOT `round` executable consumes.

pub mod batch;
pub mod cifar;
pub mod idx;
pub mod shard;
pub mod synthetic;

/// An in-memory labeled image dataset, NHWC f32 features.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[num, h*w*c]` row-major features.
    pub features: Vec<f32>,
    /// `[num]` class labels.
    pub labels: Vec<i32>,
    /// Image shape `(h, w, c)`.
    pub shape: (usize, usize, usize),
    /// Number of distinct classes (labels are `0..num_classes`).
    pub num_classes: usize,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Elements per flattened feature row (`h * w * c`).
    pub fn feature_len(&self) -> usize {
        self.shape.0 * self.shape.1 * self.shape.2
    }

    /// Sample `i`'s flattened feature row.
    pub fn feature(&self, i: usize) -> &[f32] {
        let fl = self.feature_len();
        &self.features[i * fl..(i + 1) * fl]
    }

    /// Select rows by index (used by sharding).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let fl = self.feature_len();
        let mut features = Vec::with_capacity(idx.len() * fl);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            features.extend_from_slice(self.feature(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            features,
            labels,
            shape: self.shape,
            num_classes: self.num_classes,
        }
    }

    /// Sanity checks used by tests and loaders.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.features.len() == self.len() * self.feature_len(),
            "feature buffer size mismatch"
        );
        anyhow::ensure!(
            self.labels.iter().all(|&l| (l as usize) < self.num_classes && l >= 0),
            "label out of range"
        );
        Ok(())
    }
}

/// Which benchmark dataset to materialize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28x28x1, 10 classes (Fashion-MNIST shaped).
    FashionMnist,
    /// 32x32x3, 10 classes (CIFAR-10 shaped).
    Cifar10,
}

impl DatasetKind {
    /// The benchmark's `(h, w, c)` image shape.
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::FashionMnist => (28, 28, 1),
            DatasetKind::Cifar10 => (32, 32, 3),
        }
    }

    /// Parse `fashion_mnist` / `cifar10` (and short aliases).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fashion_mnist" | "fmnist" => Ok(DatasetKind::FashionMnist),
            "cifar10" | "cifar" => Ok(DatasetKind::Cifar10),
            _ => anyhow::bail!("unknown dataset {s:?} (want fashion_mnist|cifar10)"),
        }
    }
}

/// Load `(train, test)` for `kind`: real files under `data_dir` when
/// present, synthetic otherwise.
pub fn load_or_synthesize(
    kind: DatasetKind,
    data_dir: &str,
    train_size: usize,
    test_size: usize,
    seed: u64,
) -> anyhow::Result<(Dataset, Dataset, &'static str)> {
    match kind {
        DatasetKind::FashionMnist => {
            if let Ok(pair) = idx::load_fashion_mnist(data_dir) {
                return Ok((pair.0, pair.1, "real"));
            }
        }
        DatasetKind::Cifar10 => {
            if let Ok(pair) = cifar::load_cifar10(data_dir) {
                return Ok((pair.0, pair.1, "real"));
            }
        }
    }
    // Same template seed (same task!), different sample seeds per split.
    let train = synthetic::generate_split(kind, train_size, seed, seed);
    let test = synthetic::generate_split(kind, test_size, seed, seed ^ 0x7E57_7E57);
    Ok((train, test, "synthetic"))
}
