//! Procedural class-structured image datasets.
//!
//! Stand-ins for Fashion-MNIST / CIFAR-10 when the real files are absent
//! (offline build environment).  Each class is a deterministic *template*
//! built from a few parametric strokes (bars, blobs, checkers, gradients
//! — loosely "garment-like" silhouettes); samples are the class template
//! under random shift, per-sample contrast jitter and pixel noise.  The
//! task is easy enough that the paper's models learn it within the round
//! budgets of Figs. 2-4 yet hard enough that loss/accuracy curves have
//! the fast-early / slow-late shape the adaptive policies key off
//! (Fig. 1a), which is the behaviour the reproduction must preserve.

use super::{Dataset, DatasetKind};
use crate::util::rng::Rng;

/// Number of distinct stroke primitives per class template.
const STROKES: usize = 6;

#[derive(Clone, Copy)]
struct Stroke {
    cx: f32,
    cy: f32,
    sx: f32,
    sy: f32,
    angle: f32,
    amp: f32,
    freq: f32, // 0 => solid blob, >0 => striped
}

fn class_template(kind: DatasetKind, class: usize, seed: u64) -> Vec<Stroke> {
    // Half the strokes are *shared* across classes (a common "background"
    // object) so classes overlap and the classifier has to pick up the
    // class-specific residual — that is what stretches convergence over
    // tens of federated rounds like the real benchmarks.
    let mut shared = Rng::new(seed ^ 0xBAC6_0000);
    let mut rng = Rng::new(seed ^ 0xC1A5_5000 ^ class as u64);
    let mk = |rng: &mut Rng, amp_scale: f32| Stroke {
        cx: 0.15 + 0.7 * rng.next_f32(),
        cy: 0.15 + 0.7 * rng.next_f32(),
        sx: 0.08 + 0.25 * rng.next_f32(),
        sy: 0.08 + 0.25 * rng.next_f32(),
        angle: std::f32::consts::PI * rng.next_f32(),
        amp: amp_scale * if rng.next_f32() < 0.5 { 1.0 } else { -0.6 },
        freq: if matches!(kind, DatasetKind::Cifar10) && rng.next_f32() < 0.4 {
            4.0 + 8.0 * rng.next_f32()
        } else {
            0.0
        },
    };
    let mut strokes: Vec<Stroke> = (0..STROKES / 2).map(|_| mk(&mut shared, 1.0)).collect();
    strokes.extend((0..STROKES - STROKES / 2).map(|_| mk(&mut rng, 0.55)));
    strokes
}

fn render(
    strokes: &[Stroke],
    h: usize,
    w: usize,
    c: usize,
    dx: f32,
    dy: f32,
    contrast: f32,
    chroma: &[f32],
    noise: &mut impl FnMut() -> f32,
    out: &mut [f32],
) {
    for y in 0..h {
        for x in 0..w {
            let fx = x as f32 / w as f32 - dx;
            let fy = y as f32 / h as f32 - dy;
            let mut v = 0.0f32;
            for s in strokes {
                let (sin, cos) = s.angle.sin_cos();
                let rx = (fx - s.cx) * cos + (fy - s.cy) * sin;
                let ry = -(fx - s.cx) * sin + (fy - s.cy) * cos;
                let d2 = (rx / s.sx) * (rx / s.sx) + (ry / s.sy) * (ry / s.sy);
                let mut g = (-d2).exp() * s.amp;
                if s.freq > 0.0 {
                    g *= 0.5 + 0.5 * (s.freq * rx * std::f32::consts::TAU).sin();
                }
                v += g;
            }
            v *= contrast;
            for ch in 0..c {
                let px = v * chroma[ch] + 0.45 * noise();
                out[(y * w + x) * c + ch] = px.clamp(-1.5, 1.5);
            }
        }
    }
}

/// Generate `num` labeled samples of `kind` (balanced classes, shuffled).
///
/// `template_seed` fixes the class definitions; `seed` drives per-sample
/// randomness.  Train and test splits must share `template_seed` (same
/// task!) but use different `seed`s.
pub fn generate_split(kind: DatasetKind, num: usize, template_seed: u64, seed: u64) -> Dataset {
    let (h, w, c) = kind.shape();
    let classes = 10usize;
    let templates: Vec<Vec<Stroke>> = (0..classes)
        .map(|k| class_template(kind, k, template_seed))
        .collect();
    // Per-class chroma signatures (for RGB datasets): classes differ in
    // colour as well as shape, like CIFAR's semantic classes do.
    let mut crng = Rng::new(template_seed ^ 0xC010_0FF5);
    let chromas: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..c).map(|_| 0.5 + crng.next_f32()).collect())
        .collect();

    let mut rng = Rng::new(seed);
    let fl = h * w * c;
    let mut features = vec![0.0f32; num * fl];
    let mut labels = Vec::with_capacity(num);
    for i in 0..num {
        let class = i % classes; // balanced
        let dx = 0.24 * (rng.next_f32() - 0.5);
        let dy = 0.24 * (rng.next_f32() - 0.5);
        let contrast = 0.6 + 0.8 * rng.next_f32();
        let mut noise_rng = rng.derive(&format!("noise{i}"));
        let mut noise = move || noise_rng.next_normal();
        // per-sample stroke jitter: shape deformations, not just shifts
        let jittered: Vec<Stroke> = templates[class]
            .iter()
            .map(|s| Stroke {
                cx: s.cx + 0.05 * (rng.next_f32() - 0.5),
                cy: s.cy + 0.05 * (rng.next_f32() - 0.5),
                sx: s.sx * (0.85 + 0.3 * rng.next_f32()),
                sy: s.sy * (0.85 + 0.3 * rng.next_f32()),
                angle: s.angle + 0.25 * (rng.next_f32() - 0.5),
                amp: s.amp,
                freq: s.freq,
            })
            .collect();
        render(
            &jittered,
            h,
            w,
            c,
            dx,
            dy,
            contrast,
            &chromas[class],
            &mut noise,
            &mut features[i * fl..(i + 1) * fl],
        );
        labels.push(class as i32);
    }
    // Shuffle sample order (labels and features together).
    let mut order: Vec<usize> = (0..num).collect();
    rng.shuffle(&mut order);
    let ds = Dataset {
        features,
        labels,
        shape: (h, w, c),
        num_classes: classes,
    };
    ds.subset(&order)
}

/// Single-split convenience: templates and samples share the seed.
pub fn generate(kind: DatasetKind, num: usize, seed: u64) -> Dataset {
    generate_split(kind, num, seed, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let ds = generate(DatasetKind::FashionMnist, 200, 1);
        assert_eq!(ds.len(), 200);
        assert_eq!(ds.shape, (28, 28, 1));
        ds.validate().unwrap();
        let mut counts = [0; 10];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(DatasetKind::Cifar10, 50, 7);
        let b = generate(DatasetKind::Cifar10, 50, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features, b.features);
        let c = generate(DatasetKind::Cifar10, 50, 8);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn classes_are_separable_by_template() {
        // Nearest-class-mean classification on clean features should beat
        // chance by a wide margin — otherwise the task is pure noise and
        // no model could produce the paper's convergence curves.
        let ds = generate(DatasetKind::FashionMnist, 500, 3);
        let fl = ds.feature_len();
        let mut means = vec![vec![0.0f32; fl]; 10];
        let mut counts = [0usize; 10];
        for i in 0..ds.len() {
            let l = ds.labels[i] as usize;
            for (m, &f) in means[l].iter_mut().zip(ds.feature(i)) {
                *m += f;
            }
            counts[l] += 1;
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= cnt as f32;
            }
        }
        let test = generate(DatasetKind::FashionMnist, 200, 4);
        let mut correct = 0;
        for i in 0..test.len() {
            let f = test.feature(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(f).map(|(m, x)| (m - x).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(f).map(|(m, x)| (m - x).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        // The task is deliberately hard (heavy noise, shared confuser
        // strokes) so *linear* nearest-mean only needs to beat chance
        // (0.1); the CNNs reach >0.9 (integration tests) — that contrast
        // is exactly the fast-early/slow-late dynamic we want.
        assert!(acc > 0.12, "nearest-mean accuracy only {acc}");
    }
}
