//! CIFAR-10 binary-format parser.
//!
//! The canonical distribution ships `data_batch_{1..5}.bin` + `test_batch.bin`,
//! each a sequence of 3073-byte records: `label u8 | 1024 R | 1024 G | 1024 B`
//! (channel-planar 32x32).  We convert to NHWC interleaved f32 in
//! `[-0.5, 0.5]` to match the rest of the pipeline.

use std::fs;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use super::Dataset;

const RECORD: usize = 3073;
const SIDE: usize = 32;
const PLANE: usize = SIDE * SIDE;

/// Parse one CIFAR-10 binary batch buffer into (features NHWC, labels).
pub fn parse_cifar_batch(buf: &[u8]) -> Result<(Vec<f32>, Vec<i32>)> {
    ensure!(
        !buf.is_empty() && buf.len() % RECORD == 0,
        "cifar: buffer size {} not a multiple of {RECORD}",
        buf.len()
    );
    let n = buf.len() / RECORD;
    let mut features = vec![0.0f32; n * PLANE * 3];
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        let rec = &buf[r * RECORD..(r + 1) * RECORD];
        let label = rec[0];
        ensure!(label < 10, "cifar: label {label} out of range");
        labels.push(label as i32);
        let pixels = &rec[1..];
        for y in 0..SIDE {
            for x in 0..SIDE {
                let p = y * SIDE + x;
                let o = (r * PLANE + p) * 3;
                features[o] = pixels[p] as f32 / 255.0 - 0.5;
                features[o + 1] = pixels[PLANE + p] as f32 / 255.0 - 0.5;
                features[o + 2] = pixels[2 * PLANE + p] as f32 / 255.0 - 0.5;
            }
        }
    }
    Ok((features, labels))
}

fn load_batches(paths: &[std::path::PathBuf]) -> Result<Dataset> {
    let mut features = Vec::new();
    let mut labels = Vec::new();
    for p in paths {
        let buf = fs::read(p).with_context(|| format!("read {}", p.display()))?;
        let (f, l) = parse_cifar_batch(&buf).with_context(|| format!("parse {}", p.display()))?;
        features.extend(f);
        labels.extend(l);
    }
    let ds = Dataset {
        features,
        labels,
        shape: (SIDE, SIDE, 3),
        num_classes: 10,
    };
    ds.validate()?;
    Ok(ds)
}

/// Load CIFAR-10 from `<dir>/cifar-10-batches-bin/`.
pub fn load_cifar10(dir: &str) -> Result<(Dataset, Dataset)> {
    let base = Path::new(dir).join("cifar-10-batches-bin");
    let train_paths: Vec<_> = (1..=5)
        .map(|i| base.join(format!("data_batch_{i}.bin")))
        .collect();
    let train = load_batches(&train_paths)?;
    let test = load_batches(&[base.join("test_batch.bin")])?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(label: u8, fill: u8) -> Vec<u8> {
        let mut rec = vec![label];
        rec.extend(std::iter::repeat(fill).take(RECORD - 1));
        rec
    }

    #[test]
    fn parse_single_record() {
        let (f, l) = parse_cifar_batch(&record(7, 255)).unwrap();
        assert_eq!(l, vec![7]);
        assert_eq!(f.len(), PLANE * 3);
        assert!(f.iter().all(|&x| (x - 0.5).abs() < 1e-6));
    }

    #[test]
    fn channel_interleaving() {
        // R plane = 255, G/B = 0: every pixel should be (0.5, -0.5, -0.5).
        let mut rec = vec![0u8];
        rec.extend(std::iter::repeat(255u8).take(PLANE));
        rec.extend(std::iter::repeat(0u8).take(2 * PLANE));
        let (f, _) = parse_cifar_batch(&rec).unwrap();
        assert!((f[0] - 0.5).abs() < 1e-6);
        assert!((f[1] + 0.5).abs() < 1e-6);
        assert!((f[2] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(parse_cifar_batch(&[]).is_err());
        assert!(parse_cifar_batch(&[0u8; RECORD - 1]).is_err());
        assert!(parse_cifar_batch(&record(10, 0)).is_err());
    }

    #[test]
    fn multiple_records() {
        let mut buf = record(1, 10);
        buf.extend(record(2, 20));
        let (f, l) = parse_cifar_batch(&buf).unwrap();
        assert_eq!(l, vec![1, 2]);
        assert_eq!(f.len(), 2 * PLANE * 3);
    }
}
