//! Client sharding: split a dataset across `n` federated clients.
//!
//! * [`Sharding::Iid`] — uniform random partition (the paper's setup:
//!   "training datasets ... are split among all clients").
//! * [`Sharding::Dirichlet`] — label-skewed non-IID partition with
//!   per-client class proportions drawn from Dirichlet(alpha); the
//!   standard FL heterogeneity knob (used by the ablation bench).
//!
//! Shards are index lists into the parent dataset; materialization via
//! `Dataset::subset` happens once per client at session start.

use super::Dataset;
use crate::util::rng::Rng;

/// How a dataset is partitioned across clients.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    /// Uniform random partition (the paper's setup).
    Iid,
    /// Label-distribution skew; smaller alpha = more heterogeneous.
    Dirichlet {
        /// Dirichlet concentration parameter (> 0).
        alpha: f64,
    },
}

impl Sharding {
    /// Parse `iid` or `dirichlet:<alpha>`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if s == "iid" {
            return Ok(Sharding::Iid);
        }
        if let Some(rest) = s.strip_prefix("dirichlet:") {
            let alpha: f64 = rest.parse()?;
            anyhow::ensure!(alpha > 0.0, "alpha must be positive");
            return Ok(Sharding::Dirichlet { alpha });
        }
        anyhow::bail!("unknown sharding {s:?} (want iid|dirichlet:<alpha>)")
    }
}

/// Partition `ds` into `n` index shards.  Every sample is assigned to
/// exactly one client; shards are non-empty for any reasonable `n`
/// (n <= len / num_classes).
pub fn shard_indices(ds: &Dataset, n: usize, how: Sharding, seed: u64) -> Vec<Vec<usize>> {
    assert!(n > 0, "need at least one client");
    let mut rng = Rng::new(seed).derive("shard");
    match how {
        Sharding::Iid => {
            let mut order: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut order);
            let mut shards = vec![Vec::with_capacity(ds.len() / n + 1); n];
            for (i, idx) in order.into_iter().enumerate() {
                shards[i % n].push(idx);
            }
            shards
        }
        Sharding::Dirichlet { alpha } => {
            // Group sample indices by class.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.num_classes];
            for (i, &l) in ds.labels.iter().enumerate() {
                by_class[l as usize].push(i);
            }
            let mut shards = vec![Vec::new(); n];
            for idxs in by_class.iter_mut() {
                rng.shuffle(idxs);
                let props = rng.next_dirichlet(alpha, n);
                // Largest-remainder apportionment of this class's samples.
                let total = idxs.len();
                let mut counts: Vec<usize> =
                    props.iter().map(|p| (p * total as f64) as usize).collect();
                let mut assigned: usize = counts.iter().sum();
                let mut order: Vec<usize> = (0..n).collect();
                order.sort_by(|&a, &b| {
                    let ra = props[a] * total as f64 - counts[a] as f64;
                    let rb = props[b] * total as f64 - counts[b] as f64;
                    rb.partial_cmp(&ra).unwrap()
                });
                let mut k = 0;
                while assigned < total {
                    counts[order[k % n]] += 1;
                    assigned += 1;
                    k += 1;
                }
                let mut off = 0;
                for (c, shard) in counts.iter().zip(shards.iter_mut()) {
                    shard.extend_from_slice(&idxs[off..off + c]);
                    off += c;
                }
            }
            // Guarantee non-empty shards: steal one sample from the largest.
            for i in 0..n {
                if shards[i].is_empty() {
                    let donor = (0..n).max_by_key(|&j| shards[j].len()).unwrap();
                    let moved = shards[donor].pop().expect("donor shard empty");
                    shards[i].push(moved);
                }
            }
            for s in shards.iter_mut() {
                rng.shuffle(s);
            }
            shards
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, DatasetKind};

    fn tiny() -> Dataset {
        synthetic::generate(DatasetKind::FashionMnist, 400, 11)
    }

    fn assert_partition(ds: &Dataset, shards: &[Vec<usize>]) {
        let mut seen = vec![false; ds.len()];
        for s in shards {
            for &i in s {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "some sample unassigned");
    }

    #[test]
    fn iid_is_balanced_partition() {
        let ds = tiny();
        let shards = shard_indices(&ds, 10, Sharding::Iid, 5);
        assert_partition(&ds, &shards);
        for s in &shards {
            assert_eq!(s.len(), 40);
        }
    }

    #[test]
    fn dirichlet_is_partition_and_skews() {
        let ds = tiny();
        let shards = shard_indices(&ds, 10, Sharding::Dirichlet { alpha: 0.1 }, 5);
        assert_partition(&ds, &shards);
        assert!(shards.iter().all(|s| !s.is_empty()));
        // With alpha = 0.1 at least one client should be strongly
        // class-concentrated (majority class > 50%).
        let concentrated = shards.iter().any(|s| {
            let mut counts = [0usize; 10];
            for &i in s {
                counts[ds.labels[i] as usize] += 1;
            }
            counts.iter().max().unwrap() * 2 > s.len()
        });
        assert!(concentrated, "alpha=0.1 produced near-uniform shards");
    }

    #[test]
    fn dirichlet_large_alpha_approaches_iid() {
        let ds = tiny();
        let shards = shard_indices(&ds, 4, Sharding::Dirichlet { alpha: 1000.0 }, 5);
        assert_partition(&ds, &shards);
        for s in &shards {
            let frac = s.len() as f64 / ds.len() as f64;
            assert!((frac - 0.25).abs() < 0.1, "shard fraction {frac}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = tiny();
        let a = shard_indices(&ds, 7, Sharding::Dirichlet { alpha: 0.5 }, 9);
        let b = shard_indices(&ds, 7, Sharding::Dirichlet { alpha: 0.5 }, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn parse_sharding() {
        assert_eq!(Sharding::parse("iid").unwrap(), Sharding::Iid);
        assert_eq!(
            Sharding::parse("dirichlet:0.3").unwrap(),
            Sharding::Dirichlet { alpha: 0.3 }
        );
        assert!(Sharding::parse("nope").is_err());
        assert!(Sharding::parse("dirichlet:-1").is_err());
    }
}
