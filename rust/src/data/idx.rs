//! IDX file parser (the MNIST / Fashion-MNIST distribution format).
//!
//! Big-endian magic: `0x00 0x00 <dtype> <ndim>` then `ndim` u32 dims, then
//! row-major payload.  Only u8 payloads are needed for the benchmarks;
//! images are normalized to `[-0.5, 0.5]` (mean-ish centering keeps the
//! synthetic and real pipelines on the same dynamic range).
//!
//! `load_fashion_mnist` expects the canonical four files (optionally
//! `.gz`-less — we read raw IDX) under `<dir>/fashion_mnist/`.

use std::fs;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::Dataset;

/// A parsed IDX tensor of u8 payload.
pub struct IdxU8 {
    /// Tensor dimensions, outermost first.
    pub dims: Vec<usize>,
    /// Row-major u8 payload.
    pub data: Vec<u8>,
}

/// Parse an IDX byte buffer with a u8 (0x08) payload.
pub fn parse_idx_u8(buf: &[u8]) -> Result<IdxU8> {
    ensure!(buf.len() >= 4, "idx: truncated header");
    ensure!(buf[0] == 0 && buf[1] == 0, "idx: bad magic prefix");
    let dtype = buf[2];
    if dtype != 0x08 {
        bail!("idx: unsupported dtype {dtype:#04x} (only u8)");
    }
    let ndim = buf[3] as usize;
    ensure!(ndim >= 1 && ndim <= 4, "idx: weird ndim {ndim}");
    ensure!(buf.len() >= 4 + 4 * ndim, "idx: truncated dims");
    let mut dims = Vec::with_capacity(ndim);
    for i in 0..ndim {
        let o = 4 + 4 * i;
        dims.push(u32::from_be_bytes(buf[o..o + 4].try_into().unwrap()) as usize);
    }
    let total: usize = dims.iter().product();
    let payload = &buf[4 + 4 * ndim..];
    ensure!(
        payload.len() == total,
        "idx: payload {} != dims product {total}",
        payload.len()
    );
    Ok(IdxU8 {
        dims,
        data: payload.to_vec(),
    })
}

fn read_idx(path: &Path) -> Result<IdxU8> {
    let buf = fs::read(path).with_context(|| format!("read {}", path.display()))?;
    parse_idx_u8(&buf).with_context(|| format!("parse {}", path.display()))
}

fn to_dataset(images: IdxU8, labels: IdxU8) -> Result<Dataset> {
    ensure!(images.dims.len() == 3, "images must be [n, h, w]");
    ensure!(labels.dims.len() == 1, "labels must be [n]");
    let (n, h, w) = (images.dims[0], images.dims[1], images.dims[2]);
    ensure!(labels.dims[0] == n, "image/label count mismatch");
    let features = images
        .data
        .iter()
        .map(|&b| b as f32 / 255.0 - 0.5)
        .collect();
    let labels_i = labels.data.iter().map(|&b| b as i32).collect();
    let ds = Dataset {
        features,
        labels: labels_i,
        shape: (h, w, 1),
        num_classes: 10,
    };
    ds.validate()?;
    Ok(ds)
}

/// Load the canonical Fashion-MNIST train/test pair from
/// `<dir>/fashion_mnist/{train,t10k}-{images-idx3,labels-idx1}-ubyte`.
pub fn load_fashion_mnist(dir: &str) -> Result<(Dataset, Dataset)> {
    let base = Path::new(dir).join("fashion_mnist");
    let train = to_dataset(
        read_idx(&base.join("train-images-idx3-ubyte"))?,
        read_idx(&base.join("train-labels-idx1-ubyte"))?,
    )?;
    let test = to_dataset(
        read_idx(&base.join("t10k-images-idx3-ubyte"))?,
        read_idx(&base.join("t10k-labels-idx1-ubyte"))?,
    )?;
    Ok((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut buf = vec![0, 0, 0x08, dims.len() as u8];
        for d in dims {
            buf.extend_from_slice(&d.to_be_bytes());
        }
        buf.extend_from_slice(payload);
        buf
    }

    #[test]
    fn parse_roundtrip() {
        let buf = make_idx(&[2, 3], &[1, 2, 3, 4, 5, 6]);
        let t = parse_idx_u8(&buf).unwrap();
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(parse_idx_u8(&[]).is_err());
        assert!(parse_idx_u8(&[1, 0, 8, 1, 0, 0, 0, 0]).is_err()); // bad prefix
        assert!(parse_idx_u8(&make_idx(&[5], &[0; 4])).is_err()); // short payload
        let mut f64_type = make_idx(&[1], &[0]);
        f64_type[2] = 0x0E;
        assert!(parse_idx_u8(&f64_type).is_err()); // unsupported dtype
    }

    #[test]
    fn dataset_conversion_normalizes() {
        let images = parse_idx_u8(&make_idx(&[2, 2, 2], &[0, 255, 128, 64, 0, 0, 255, 255])).unwrap();
        let labels = parse_idx_u8(&make_idx(&[2], &[3, 9])).unwrap();
        let ds = to_dataset(images, labels).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.shape, (2, 2, 1));
        assert!((ds.features[0] + 0.5).abs() < 1e-6);
        assert!((ds.features[1] - 0.5).abs() < 1e-6);
        assert_eq!(ds.labels, vec![3, 9]);
    }

    #[test]
    fn missing_files_error_cleanly() {
        assert!(load_fashion_mnist("/nonexistent").is_err());
    }
}
