//! Round-batch assembly: each federated round, a client runs `tau` local
//! SGD steps over minibatches of size `B`; the AOT `round` executable takes
//! them as one `[tau, B, ...]` tensor.  [`BatchCursor`] walks a client's
//! shard in shuffled epochs, reshuffling at epoch boundaries, and fills a
//! reusable buffer (no per-round allocation on the hot path).

use super::Dataset;
use crate::util::rng::Rng;

/// Epoch-shuffling cursor over one client's local dataset.
pub struct BatchCursor {
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl BatchCursor {
    /// Cursor over a shard of `len` samples, shuffled by `rng`.
    pub fn new(len: usize, rng: Rng) -> Self {
        assert!(len > 0, "empty shard");
        let mut c = BatchCursor {
            order: (0..len).collect(),
            pos: 0,
            rng,
        };
        c.reshuffle();
        c
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.pos = 0;
    }

    /// Next sample index (wraps across epochs, reshuffling).
    #[inline]
    pub fn next_index(&mut self) -> usize {
        if self.pos >= self.order.len() {
            self.reshuffle();
        }
        let i = self.order[self.pos];
        self.pos += 1;
        i
    }

    /// Fill `xs [tau*B*feat]` / `ys [tau*B]` with the next round batch.
    pub fn fill_round_batch(
        &mut self,
        ds: &Dataset,
        tau: usize,
        batch: usize,
        xs: &mut [f32],
        ys: &mut [i32],
    ) {
        let fl = ds.feature_len();
        debug_assert_eq!(xs.len(), tau * batch * fl);
        debug_assert_eq!(ys.len(), tau * batch);
        for s in 0..tau * batch {
            let i = self.next_index();
            xs[s * fl..(s + 1) * fl].copy_from_slice(ds.feature(i));
            ys[s] = ds.labels[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, DatasetKind};

    #[test]
    fn covers_every_sample_each_epoch() {
        let mut c = BatchCursor::new(10, Rng::new(1));
        for _epoch in 0..3 {
            let mut seen = [false; 10];
            for _ in 0..10 {
                seen[c.next_index()] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn fill_shapes_and_content() {
        let ds = synthetic::generate(DatasetKind::FashionMnist, 40, 2);
        let (tau, b) = (3, 4);
        let fl = ds.feature_len();
        let mut xs = vec![0.0f32; tau * b * fl];
        let mut ys = vec![0i32; tau * b];
        let mut c = BatchCursor::new(ds.len(), Rng::new(3));
        c.fill_round_batch(&ds, tau, b, &mut xs, &mut ys);
        assert!(ys.iter().all(|&y| (0..10).contains(&y)));
        // every copied feature row must match its label's source row
        assert!(xs.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn wraps_small_shards() {
        let ds = synthetic::generate(DatasetKind::FashionMnist, 5, 2);
        let mut c = BatchCursor::new(ds.len(), Rng::new(4));
        let fl = ds.feature_len();
        let mut xs = vec![0.0f32; 4 * 8 * fl];
        let mut ys = vec![0i32; 4 * 8];
        // tau*B = 32 > 5 samples: must wrap without panicking
        c.fill_round_batch(&ds, 4, 8, &mut xs, &mut ys);
    }
}
