//! Million-client scale smoke: the scheduler must plan cohorts without
//! touching the full registry, and per-client resident state must stay
//! below the fp32 baseline it replaced.
//!
//! These tests exercise only the control plane (scheduler, arena,
//! residual bank) — no model runtime — so they run in seconds even at
//! `n = 1_000_000`.  CI runs them in release as the `scale-smoke` job;
//! the wall-clock budget below is generous enough for debug builds too.

use std::time::Instant;

use feddq::config::RunConfig;
use feddq::coordinator::{ClientArena, ResidualBank, RoundScheduler};

const N: usize = 1_000_000;

#[test]
fn million_client_round_planning_is_sparse_and_fast() {
    let mut cfg = RunConfig::default_for("mlp");
    cfg.round.cohort.participation = 0.001;
    let sched = RoundScheduler::from_config(&cfg, N).expect("scheduler");

    // ceil(0.001 * 1e6) computed in f32: the knob's representation sits
    // a hair above 0.001, so the ceil may land on 1001.
    let k = sched.cohort_target();
    assert!(
        (1000..=1001).contains(&k),
        "cohort target {k} out of the expected 1000..=1001"
    );

    let t0 = Instant::now();
    for m in 0..10u32 {
        let plan = sched.plan_round(m);
        assert_eq!(plan.round, m);
        assert_eq!(plan.selected.len(), k, "round {m}: cohort size");
        assert!(
            plan.selected.windows(2).all(|w| w[0] < w[1]),
            "round {m}: selected must be strictly ascending (the fold order)"
        );
        assert!(
            (*plan.selected.last().unwrap() as usize) < N,
            "round {m}: selected id out of the registry"
        );
        // Dispatch reorders the cohort but never changes its membership.
        let mut dispatch = plan.dispatch.clone();
        dispatch.sort_unstable();
        assert_eq!(
            dispatch,
            plan.selected,
            "round {m}: dispatch must be a permutation of selected"
        );
        // No deadline policy in this config, so nothing is cut.
        assert_eq!(plan.dropped, 0, "round {m}: unexpected deadline drops");
    }
    let secs = t0.elapsed().as_secs_f64();
    // The dense sampler this replaced shuffled a million-entry vector
    // per round; the sparse draw does O(k) work.  Ten rounds take
    // milliseconds in release — budget minutes of headroom for debug
    // builds on loaded CI boxes.
    assert!(
        secs < 20.0,
        "10 rounds of 1M-client planning took {secs:.2}s (budget 20s)"
    );
}

#[test]
fn cohort_draws_differ_across_rounds_but_replay_within_one() {
    let mut cfg = RunConfig::default_for("mlp");
    cfg.round.cohort.participation = 0.001;
    let sched = RoundScheduler::from_config(&cfg, N).expect("scheduler");

    let a = sched.plan_round(0);
    let b = sched.plan_round(1);
    assert_ne!(a.selected, b.selected, "rounds must draw distinct cohorts");
    // Pure in (seed, round): replanning the same round replays exactly.
    let a2 = sched.plan_round(0);
    assert_eq!(a.selected, a2.selected);
    assert_eq!(a.dispatch, a2.dispatch);
}

#[test]
fn arena_holds_a_million_clients_in_twenty_four_bytes_each() {
    let mut arena = ClientArena::new();
    for id in 0..N as u32 {
        arena.set_samples(id, 60);
        // the per-client wire ledger lives in the same row — no side maps
        arena.add_io_bytes(id, 1_000, 4_000);
    }
    assert_eq!(arena.len(), N);
    // The whole registry: 24 MB (samples + EWMA + io ledger), vs the
    // 48+ bytes/entry the old BTreeMap-samples + dense-f64-EWMA +
    // per-handle byte-counter spread cost.
    assert_eq!(arena.resident_bytes(), (N * 24) as u64);
    // The per-client budget `client_state_bytes` reports must hold.
    assert!(arena.resident_bytes() <= (N as u64) * 24);
    assert_eq!(arena.io_bytes((N - 1) as u32), (1_000, 4_000));

    // Reading ids that never reported stays free: no row materializes.
    let sparse = ClientArena::new();
    assert_eq!(sparse.samples((N - 1) as u32), None);
    assert_eq!(sparse.resident_bytes(), 0);
}

#[test]
fn banked_residuals_are_sub_fp32_with_bounded_error() {
    // One EF residual per client dominates client-side memory at scale;
    // banked at 8 bits it must cost strictly less than the 4 bytes per
    // element an fp32 buffer would.
    let d = 100_000usize;
    let spans = [(0usize, 60_000usize), (60_000, 40_000)];
    let values: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();

    let bank = ResidualBank::bank(&spans, &values, 8);
    assert!(
        bank.resident_bytes() < d * 4,
        "banked residual ({} B) must undercut fp32 ({} B)",
        bank.resident_bytes(),
        d * 4
    );

    // Reconstruction error is bounded by step/2 on each span's grid.
    let mut out = vec![0.0f32; d];
    bank.dequantize_into(&spans, &mut out);
    for &(off, size) in &spans {
        let seg = &values[off..off + size];
        let mn = seg.iter().copied().fold(f32::INFINITY, f32::min);
        let mx = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let step = (mx - mn) / 255.0;
        for j in off..off + size {
            let err = (out[j] - values[j]).abs();
            assert!(
                err <= step * 0.5 + 1e-6,
                "element {j}: banking error {err} exceeds step/2 = {}",
                step * 0.5
            );
        }
    }
}
