//! The parallel round engine's determinism contract: for any worker
//! thread count, accumulator shard count, eval slice count,
//! decode-buffer bound, fold-overlap setting, codec path (narrow u16
//! rows + SWAR kernels + fused encode vs the scalar f32 reference)
//! **and participation knobs** (sampled cohorts, deadline policy,
//! simulated latency) the in-process `Session` must produce a
//! bit-identical `RunReport` — same round records, same bit ledger,
//! same cohorts, same final parameter hash.  Also pins the
//! streaming-vs-fused aggregation equivalence on the mlp config.

use feddq::config::{AggregateMode, CodecMode, RunConfig};
use feddq::coordinator::sched::RoundScheduler;
use feddq::coordinator::Session;
use feddq::metrics::RunReport;
use feddq::quant::PolicyConfig;
use feddq::sim::faults::FaultProfile;
use feddq::sim::latency::{LatencyModel, LatencyProfile};

fn mlp_cfg(threads: usize) -> RunConfig {
    let mut cfg = RunConfig::default_for("mlp");
    cfg.policy = PolicyConfig::FedDq { resolution: 0.005 };
    cfg.rounds = 4;
    cfg.train_size = 600;
    cfg.test_size = 500; // one eval batch
    cfg.threads = threads;
    cfg
}

fn run(cfg: RunConfig) -> RunReport {
    Session::new(cfg).unwrap().run().unwrap()
}

/// Bitwise equality of two reports (NaN-tolerant via f32 bit patterns).
fn assert_reports_identical(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "{what}: train_loss r{}", ra.round);
        assert_eq!(ra.test_loss.to_bits(), rb.test_loss.to_bits(), "{what}: test_loss r{}", ra.round);
        assert_eq!(
            ra.test_accuracy.to_bits(),
            rb.test_accuracy.to_bits(),
            "{what}: test_accuracy r{}",
            ra.round
        );
        assert_eq!(ra.uplink_bits, rb.uplink_bits, "{what}: uplink_bits r{}", ra.round);
        assert_eq!(ra.cum_uplink_bits, rb.cum_uplink_bits, "{what}: cum bits r{}", ra.round);
        assert_eq!(ra.mean_bits.to_bits(), rb.mean_bits.to_bits(), "{what}: mean_bits r{}", ra.round);
        assert_eq!(ra.mean_range.to_bits(), rb.mean_range.to_bits(), "{what}: mean_range r{}", ra.round);
        let sa: Vec<u32> = ra.seg_ranges.iter().map(|x| x.to_bits()).collect();
        let sb: Vec<u32> = rb.seg_ranges.iter().map(|x| x.to_bits()).collect();
        assert_eq!(sa, sb, "{what}: seg_ranges r{}", ra.round);
        // scheduler outputs are part of the contract: cohort size,
        // deadline drops, simulated makespan and the fault-model failed
        // set are seed-pure
        assert_eq!(ra.selected, rb.selected, "{what}: selected r{}", ra.round);
        assert_eq!(ra.dropped, rb.dropped, "{what}: dropped r{}", ra.round);
        assert_eq!(
            ra.sim_makespan_secs.to_bits(),
            rb.sim_makespan_secs.to_bits(),
            "{what}: sim_makespan r{}",
            ra.round
        );
        assert_eq!(ra.failed, rb.failed, "{what}: failed r{}", ra.round);
        assert_eq!(ra.rejoined, rb.rejoined, "{what}: rejoined r{}", ra.round);
        assert_eq!(ra.stale_folded, rb.stale_folded, "{what}: stale_folded r{}", ra.round);
        assert_eq!(ra.stale_dropped, rb.stale_dropped, "{what}: stale_dropped r{}", ra.round);
        assert_eq!(
            ra.subtree_failed,
            rb.subtree_failed,
            "{what}: subtree_failed r{}",
            ra.round
        );
        assert_eq!(ra.degraded, rb.degraded, "{what}: degraded r{}", ra.round);
        // the downlink ledger is analytic and fanout-blind: counted per
        // dispatched leaf from seed-pure state, so it is part of the
        // bit-identical contract like the uplink ledger
        assert_eq!(ra.downlink_bits, rb.downlink_bits, "{what}: downlink_bits r{}", ra.round);
        assert_eq!(
            ra.cum_downlink_bits,
            rb.cum_downlink_bits,
            "{what}: cum_downlink_bits r{}",
            ra.round
        );
    }
    assert_ne!(a.params_hash, 0, "{what}: params hash must be tracked");
    assert_eq!(a.params_hash, b.params_hash, "{what}: final params diverged");
}

#[test]
fn threads_1_and_4_produce_identical_reports() {
    let seq = run(mlp_cfg(1));
    let par = run(mlp_cfg(4));
    assert_reports_identical(&seq, &par, "threads=1 vs threads=4");
}

#[test]
fn auto_threads_matches_sequential() {
    let seq = run(mlp_cfg(1));
    let auto = run(mlp_cfg(0)); // min(n_clients, cores)
    assert_reports_identical(&seq, &auto, "threads=1 vs auto");
}

#[test]
fn determinism_holds_under_error_feedback_and_fixed_bits() {
    // EF keeps per-client residual state alive across rounds — the
    // moved-state pool path must preserve it exactly.
    let mut a = mlp_cfg(1);
    a.policy = PolicyConfig::Fixed { bits: 2 };
    a.error_feedback = true;
    let mut b = mlp_cfg(3);
    b.policy = PolicyConfig::Fixed { bits: 2 };
    b.error_feedback = true;
    assert_reports_identical(&run(a), &run(b), "EF threads=1 vs threads=3");
}

#[test]
fn sharded_aggregation_matches_serial_fold() {
    // Sharding splits the accumulator into contiguous element ranges;
    // per-element arithmetic and client order are unchanged, so any
    // shard count must reproduce the serial fold bit for bit — down to
    // params_hash.
    let mut serial = mlp_cfg(2);
    serial.agg_shards = 1;
    let mut sharded = mlp_cfg(2);
    sharded.agg_shards = 5; // deliberately != threads and != clients
    assert_reports_identical(&run(serial), &run(sharded), "agg_shards=1 vs 5");
}

#[test]
fn parallel_eval_matches_serial_eval() {
    // Multi-batch test set so eval actually splits across slices; the
    // reduction walks batches in order, so slice count cannot matter.
    let mut serial = mlp_cfg(2);
    serial.test_size = 1500; // three eval batches
    serial.eval_threads = 1;
    let mut parallel = mlp_cfg(2);
    parallel.test_size = 1500;
    parallel.eval_threads = 4; // clamps to 3 slices internally
    assert_reports_identical(&run(serial), &run(parallel), "eval_threads=1 vs 4");
}

#[test]
fn fully_parallel_server_matches_fully_serial_server() {
    // The whole matrix at once: threads x shards x eval slices against
    // the all-serial configuration.
    let mut serial = mlp_cfg(1);
    serial.test_size = 1000;
    serial.agg_shards = 1;
    serial.eval_threads = 1;
    let mut parallel = mlp_cfg(4);
    parallel.test_size = 1000;
    parallel.agg_shards = 3;
    parallel.eval_threads = 2;
    assert_reports_identical(
        &run(serial),
        &run(parallel),
        "serial server vs threads=4/agg_shards=3/eval_threads=2",
    );
}

#[test]
fn fold_overlap_matches_after_barrier_fold() {
    // The fold-overlap path folds each client into every shard as its
    // decode lands instead of waiting for the barrier; the per-shard
    // client order and arithmetic are unchanged, so on vs off must be
    // bit-identical — including params_hash.
    let mut off = mlp_cfg(3);
    off.agg_shards = 4;
    off.round.pipeline.fold_overlap = false;
    let mut on = mlp_cfg(3);
    on.agg_shards = 4;
    on.round.pipeline.fold_overlap = true;
    assert_reports_identical(&run(off), &run(on), "fold_overlap off vs on");
}

#[test]
fn decode_buffer_bound_cannot_change_results() {
    // decode_buffers only changes *when* a buffer is reused, never what
    // lands in it: 0 (unbounded), a tight bound of 2, and one-per-client
    // (n = 10 for the builtin mlp cohort) must all be bit-identical.
    let mut unbounded = mlp_cfg(3);
    unbounded.round.pipeline.decode_buffers = 0;
    let base = run(unbounded);
    for k in [2usize, 10] {
        let mut capped = mlp_cfg(3);
        capped.round.pipeline.decode_buffers = k;
        assert_reports_identical(
            &base,
            &run(capped),
            &format!("decode_buffers=0 vs {k}"),
        );
    }
}

#[test]
fn scheduler_knob_matrix_matches_all_serial() {
    // The PR 3 matrix: two-lane pool + bounded buffers + fold overlap
    // crossed with the existing threads/shards/eval knobs, against the
    // fully serial server.
    let mut serial = mlp_cfg(1);
    serial.test_size = 1500; // three eval batches
    serial.agg_shards = 1;
    serial.eval_threads = 1;
    serial.round.pipeline.fold_overlap = false;
    let mut parallel = mlp_cfg(4);
    parallel.test_size = 1500;
    parallel.agg_shards = 5;
    parallel.eval_threads = 3;
    parallel.round.pipeline.fold_overlap = true;
    parallel.round.pipeline.decode_buffers = 2; // hard bound, far below n_clients = 10
    assert_reports_identical(
        &run(serial),
        &run(parallel),
        "all-serial vs threads=4/shards=5/eval=3/overlap/buffers=2",
    );
}

#[test]
fn tight_decode_bound_under_error_feedback_stays_deterministic() {
    // EF keeps residual state on every client while the bounded
    // pipeline serializes decodes through a single buffer — the
    // harshest recycling schedule must still be bit-identical.
    let mut a = mlp_cfg(2);
    a.policy = PolicyConfig::Fixed { bits: 2 };
    a.error_feedback = true;
    a.round.pipeline.fold_overlap = false;
    let mut b = mlp_cfg(4);
    b.policy = PolicyConfig::Fixed { bits: 2 };
    b.error_feedback = true;
    b.round.pipeline.fold_overlap = true;
    b.round.pipeline.decode_buffers = 1;
    b.agg_shards = 3;
    assert_reports_identical(&run(a), &run(b), "EF: overlap+buffers=1 vs plain");
}

#[test]
fn narrow_swar_codec_matches_scalar_reference_path() {
    // The tentpole contract of the narrow-codec rewrite: u16 rows,
    // SWAR unpack and the client's fused quantize→pack must reproduce
    // the scalar reference path bit for bit — across the existing
    // threads/shards/overlap/buffers knob matrix, not just serially.
    let mut reference = mlp_cfg(1);
    reference.round.pipeline.codec = CodecMode::Reference;
    let base = run(reference);

    // narrow, fully serial
    let mut narrow_serial = mlp_cfg(1);
    narrow_serial.round.pipeline.codec = CodecMode::Narrow;
    assert_reports_identical(&base, &run(narrow_serial), "reference vs narrow (serial)");

    // narrow under the full parallel knob matrix
    let mut narrow_par = mlp_cfg(4);
    narrow_par.round.pipeline.codec = CodecMode::Narrow;
    narrow_par.agg_shards = 5;
    narrow_par.eval_threads = 3;
    narrow_par.round.pipeline.fold_overlap = true;
    narrow_par.round.pipeline.decode_buffers = 2;
    assert_reports_identical(
        &base,
        &run(narrow_par),
        "reference serial vs narrow threads=4/shards=5/eval=3/overlap/buffers=2",
    );

    // and the mirror image: reference path on the parallel server
    let mut reference_par = mlp_cfg(3);
    reference_par.round.pipeline.codec = CodecMode::Reference;
    reference_par.agg_shards = 4;
    reference_par.round.pipeline.fold_overlap = true;
    reference_par.round.pipeline.decode_buffers = 1;
    assert_reports_identical(
        &base,
        &run(reference_par),
        "reference serial vs reference threads=3/shards=4/overlap/buffers=1",
    );
}

#[test]
fn narrow_codec_matches_reference_under_error_feedback() {
    // The fused encoder also produces the EF residual; its banked
    // state feeds the *next* round's update, so any deviation would
    // compound — crossing codec paths with EF pins the residual
    // expression bit for bit.
    let mut reference = mlp_cfg(2);
    reference.policy = PolicyConfig::Fixed { bits: 2 };
    reference.error_feedback = true;
    reference.round.pipeline.codec = CodecMode::Reference;
    let mut narrow = mlp_cfg(4);
    narrow.policy = PolicyConfig::Fixed { bits: 2 };
    narrow.error_feedback = true;
    narrow.round.pipeline.codec = CodecMode::Narrow;
    narrow.agg_shards = 3;
    narrow.round.pipeline.decode_buffers = 1;
    assert_reports_identical(
        &run(reference),
        &run(narrow),
        "EF: reference vs narrow/fused encode",
    );
}

#[test]
fn narrow_codec_matches_reference_on_fp32_policy() {
    // fp32 uplink exercises the mixed-row decoder (f32 rows through
    // the same narrow DecodedUpdate) rather than the SWAR unpackers.
    let mut reference = mlp_cfg(2);
    reference.policy = PolicyConfig::Fp32;
    reference.round.pipeline.codec = CodecMode::Reference;
    let mut narrow = mlp_cfg(3);
    narrow.policy = PolicyConfig::Fp32;
    narrow.round.pipeline.codec = CodecMode::Narrow;
    assert_reports_identical(&run(reference), &run(narrow), "fp32: reference vs narrow");
}

#[test]
fn partial_participation_is_deterministic_across_the_knob_matrix() {
    // The acceptance matrix: participation in {1.0, 0.5, 0.2} crossed
    // against threads / shards / eval slices / fold overlap / decode
    // buffers / codec path.  The all-serial reference-codec run must be
    // bit-identical to the maximally parallel narrow-codec run at every
    // participation level — including params_hash and the per-round
    // selected counts.
    for &p in &[1.0f32, 0.5, 0.2] {
        let mut serial = mlp_cfg(1);
        serial.round.cohort.participation = p;
        serial.agg_shards = 1;
        serial.eval_threads = 1;
        serial.round.pipeline.fold_overlap = false;
        serial.round.pipeline.codec = CodecMode::Reference;
        let base = run(serial);
        let k = (10.0 * p).ceil() as u32; // builtin mlp cohort is 10
        for r in &base.rounds {
            assert_eq!(r.selected, k, "participation {p}: round {} cohort", r.round);
            assert_eq!(r.dropped, 0, "no deadline policy, nothing dropped");
        }
        let mut par = mlp_cfg(4);
        par.round.cohort.participation = p;
        par.agg_shards = 5;
        par.eval_threads = 3;
        par.round.pipeline.fold_overlap = true;
        par.round.pipeline.decode_buffers = 2;
        par.round.pipeline.codec = CodecMode::Narrow;
        assert_reports_identical(
            &base,
            &run(par),
            &format!("participation={p}: all-serial/reference vs threads=4/shards=5/eval=3/overlap/buffers=2/narrow"),
        );
    }
}

#[test]
fn sampled_cohorts_are_reproducible_from_the_seed_alone() {
    // Directly on the scheduler: the selected set is a pure function of
    // (seed, round, n, participation) — observations cannot move it.
    let fresh = || {
        RoundScheduler::new(10, 0.5, None, LatencyModel::new(LatencyProfile::Off, 17), 17)
            .unwrap()
    };
    let a = fresh();
    let mut b = fresh();
    b.observe(0, 50.0); // dispatch heuristic input, not selection input
    for m in 0..10u32 {
        assert_eq!(a.plan_round(m).selected, b.plan_round(m).selected, "round {m}");
    }
    // And end-to-end: two identical sampled runs agree bit for bit.
    let mk = || {
        let mut c = mlp_cfg(2);
        c.round.cohort.participation = 0.5;
        c
    };
    assert_reports_identical(&run(mk()), &run(mk()), "sampled run repeated");
}

#[test]
fn deadline_policy_is_deterministic_and_respects_the_budget() {
    // Straggler-aware deadline selection under a heavy-tailed simulated
    // latency: candidates are over-sampled 2x, priced, and cut
    // deterministically — the whole thing crossed against the parallel
    // server must stay bit-identical.
    let knobs = |threads: usize| {
        let mut c = mlp_cfg(threads);
        c.round.cohort.participation = 0.5;
        c.round.cohort.deadline = Some(2.0);
        c.sim_latency = LatencyProfile::LogNormal { median: 1.0, sigma: 0.6 };
        c
    };
    let serial = {
        let mut c = knobs(1);
        c.agg_shards = 1;
        c.eval_threads = 1;
        c.round.pipeline.fold_overlap = false;
        c.round.pipeline.codec = CodecMode::Reference;
        c
    };
    let parallel = {
        let mut c = knobs(4);
        c.agg_shards = 3;
        c.eval_threads = 2;
        c.round.pipeline.fold_overlap = true;
        c.round.pipeline.decode_buffers = 2;
        c
    };
    let base = run(serial);
    assert_reports_identical(&base, &run(parallel), "deadline: serial vs parallel");
    for r in &base.rounds {
        assert!(r.selected >= 1 && r.selected <= 5, "round {}: cohort {}", r.round, r.selected);
        // candidates = min(2 * ceil(0.5 * 10), 10) = 10
        assert_eq!(r.selected + r.dropped, 10, "round {}", r.round);
        if r.selected > 1 {
            assert!(
                r.sim_makespan_secs <= 2.0,
                "round {}: makespan {} breaches the deadline",
                r.round,
                r.sim_makespan_secs
            );
        }
    }
}

#[test]
fn error_feedback_residuals_survive_skipped_rounds() {
    // With a sampled cohort a client can sit out rounds; its banked EF
    // residual must stay untouched until it is next selected, and the
    // whole trajectory must be thread-count independent.
    let knobs = |threads: usize| {
        let mut c = mlp_cfg(threads);
        c.rounds = 6; // enough for cohorts to rotate
        c.round.cohort.participation = 0.5;
        c.policy = PolicyConfig::Fixed { bits: 2 };
        c.error_feedback = true;
        c
    };
    let a = run(knobs(1));
    let mut bcfg = knobs(4);
    bcfg.agg_shards = 3;
    bcfg.round.pipeline.decode_buffers = 1;
    assert_reports_identical(&a, &run(bcfg), "EF + participation: threads=1 vs 4");
    // Sanity: EF with skips still changes the trajectory vs EF-off.
    let mut plain = knobs(1);
    plain.error_feedback = false;
    let b = run(plain);
    assert_ne!(
        a.rounds.last().unwrap().train_loss.to_bits(),
        b.rounds.last().unwrap().train_loss.to_bits(),
        "EF must alter the sampled trajectory"
    );
}

#[test]
fn crash_faults_are_deterministic_across_the_knob_matrix() {
    // The PR 6 acceptance matrix: a crash fault profile crossed against
    // threads / shards / eval slices / fold overlap / decode buffers /
    // codec path.  The failed set of a round is a seeded pure function
    // of (seed, round, client id) — never of arrival order — so the
    // all-serial reference-codec run must be bit-identical to the
    // maximally parallel narrow-codec run, including params_hash and
    // the per-round failed counts; and a crash:0.3 profile over a
    // 10-client cohort must actually fail someone.
    let knobs = |threads: usize| {
        let mut c = mlp_cfg(threads);
        c.rounds = 6; // enough for fault draws to land and cohorts to rotate
        c.sim_faults = FaultProfile::Crash { p: 0.3 };
        c
    };
    let serial = {
        let mut c = knobs(1);
        c.agg_shards = 1;
        c.eval_threads = 1;
        c.round.pipeline.fold_overlap = false;
        c.round.pipeline.codec = CodecMode::Reference;
        c
    };
    let base = run(serial);
    let total_failed: u32 = base.rounds.iter().map(|r| r.failed).sum();
    assert!(total_failed > 0, "crash:0.3 over 6 rounds of 10 clients must fail someone");
    assert_eq!(base.rounds.len(), 6, "faulty rounds must all complete");
    for r in &base.rounds {
        assert_eq!(r.selected, 10, "failed members still count as selected");
        assert!(r.failed < 10, "the lowest-id survivor guarantee");
    }
    let parallel = {
        let mut c = knobs(4);
        c.agg_shards = 5;
        c.eval_threads = 3;
        c.round.pipeline.fold_overlap = true;
        c.round.pipeline.decode_buffers = 2;
        c.round.pipeline.codec = CodecMode::Narrow;
        c
    };
    assert_reports_identical(
        &base,
        &run(parallel),
        "crash faults: all-serial/reference vs threads=4/shards=5/eval=3/overlap/buffers=2/narrow",
    );
}

#[test]
fn faults_compose_with_partial_participation_and_error_feedback() {
    // A client can now miss a round two ways — unselected or crashed —
    // and both must bank its EF residual and batch cursor identically
    // across thread counts.
    let knobs = |threads: usize| {
        let mut c = mlp_cfg(threads);
        c.rounds = 6;
        c.round.cohort.participation = 0.5;
        c.sim_faults = FaultProfile::Crash { p: 0.3 };
        c.policy = PolicyConfig::Fixed { bits: 2 };
        c.error_feedback = true;
        c
    };
    let a = run(knobs(1));
    let mut b = knobs(4);
    b.agg_shards = 3;
    b.round.pipeline.decode_buffers = 1;
    assert_reports_identical(&a, &run(b), "EF + participation + crash: threads=1 vs 4");
}

#[test]
fn sim_faults_compose_with_tree_fanout_across_the_knob_matrix() {
    // The faults x topology composition contract: fault draws are pure
    // in (seed, leaf id, round) — never in topology — and the virtual
    // grouping excludes failed leaves identically at every fanout.  So
    // for each (profile, fanout) cell the all-serial reference-codec
    // run must be bit-identical to the maximally parallel narrow-codec
    // run, including the failed counts, params_hash and the
    // subtree_failed/degraded columns (always zero here: simulated
    // faults kill leaves, never aggregator processes).
    let profiles: &[(&str, FaultProfile, bool)] = &[
        ("crash", FaultProfile::Crash { p: 0.3 }, false),
        ("flaky", FaultProfile::Flaky { p: 0.3 }, false),
        ("stall", FaultProfile::Stall { p: 0.5, secs: 60.0 }, true),
    ];
    for &(name, profile, tolerant) in profiles {
        for fanout in [0u32, 2, 4] {
            let knobs = |threads: usize| {
                let mut c = mlp_cfg(threads);
                c.rounds = 5;
                c.sim_faults = profile;
                c.round.topology.fanout = fanout;
                if tolerant {
                    // stalled members overshoot this budget in simulated
                    // time and land in the failed set (staleness 0)
                    c.round.tolerance.round_timeout = Some(30.0);
                    c.round.tolerance.quorum = 0.1;
                }
                c
            };
            let serial = {
                let mut c = knobs(1);
                c.agg_shards = 1;
                c.eval_threads = 1;
                c.round.pipeline.fold_overlap = false;
                c.round.pipeline.codec = CodecMode::Reference;
                c
            };
            let base = run(serial);
            assert_eq!(base.rounds.len(), 5, "{name}/fanout={fanout}: faulty rounds complete");
            let total_failed: u32 = base.rounds.iter().map(|r| r.failed).sum();
            assert!(total_failed > 0, "{name}/fanout={fanout}: the profile must fail someone");
            for r in &base.rounds {
                assert_eq!(r.subtree_failed, 0, "{name}/fanout={fanout}: sim faults kill leaves");
                assert_eq!(r.degraded, 0, "{name}/fanout={fanout}: sim faults never degrade");
                if fanout > 0 {
                    assert_eq!(r.agg_depth, 2, "{name}/fanout={fanout}: one tier above leaves");
                } else {
                    assert_eq!(r.agg_depth, 0, "{name}: flat topology reports depth 0");
                }
            }
            let parallel = {
                let mut c = knobs(4);
                c.agg_shards = 5;
                c.eval_threads = 3;
                c.round.pipeline.fold_overlap = true;
                c.round.pipeline.decode_buffers = 2;
                c.round.pipeline.codec = CodecMode::Narrow;
                c
            };
            assert_reports_identical(
                &base,
                &run(parallel),
                &format!("{name}/fanout={fanout}: serial-ref vs parallel-narrow"),
            );
        }
    }
}

#[test]
fn semisync_staleness_composes_with_the_tree() {
    // Bounded staleness under the tree: stalled leaves (s = 2 against
    // --staleness 2) are excluded from the on-time grouping, banked at
    // dispatch, and folded with discounted weight two rounds later —
    // with the grouping applied only to the on-time survivors.  The
    // whole composition must stay engine-invariant for every fanout.
    for fanout in [2u32, 4] {
        let mut serial = semisync_cfg(1, 0.5, 2);
        serial.round.topology.fanout = fanout;
        serial.agg_shards = 1;
        serial.eval_threads = 1;
        serial.round.pipeline.fold_overlap = false;
        serial.round.pipeline.codec = CodecMode::Reference;
        let mut parallel = semisync_cfg(4, 0.5, 2);
        parallel.round.topology.fanout = fanout;
        parallel.agg_shards = 3;
        parallel.eval_threads = 2;
        parallel.round.pipeline.fold_overlap = true;
        parallel.round.pipeline.decode_buffers = 2;
        parallel.round.pipeline.codec = CodecMode::Narrow;
        let (rs, rp) = (run(serial), run(parallel));
        assert_reports_identical(
            &rs,
            &rp,
            &format!("staleness=2/fanout={fanout}: serial-ref vs parallel-narrow"),
        );
        let folded: u32 = rs.rounds.iter().map(|r| r.stale_folded).sum();
        assert!(folded > 0, "fanout={fanout}: stragglers must bank and fold under the tree");
    }
}

#[test]
fn stall_faults_against_a_round_timeout_stay_deterministic() {
    // Stalled clients (60 simulated seconds) against a 30-second
    // `--round-timeout`: every stall draw times out in *simulated*
    // time, while the real in-process round finishes in milliseconds —
    // so the tolerant receive path (switched on by the timeout/quorum
    // knobs) never trips its real-time budget and the failed set stays
    // seed-pure.
    let knobs = |threads: usize| {
        let mut c = mlp_cfg(threads);
        c.sim_faults = FaultProfile::Stall { p: 0.5, secs: 60.0 };
        c.round.tolerance.round_timeout = Some(30.0);
        c.round.tolerance.quorum = 0.1;
        c
    };
    let base = run(knobs(1));
    let total_failed: u32 = base.rounds.iter().map(|r| r.failed).sum();
    assert!(total_failed > 0, "stall:0.5:60 against a 30s timeout must fail someone");
    assert_eq!(base.rounds.len(), 4, "timed-out rounds must still complete");
    assert_reports_identical(&base, &run(knobs(4)), "stall+timeout: threads=1 vs 4");
}

#[test]
fn streaming_and_fused_aggregation_agree() {
    let mut s = mlp_cfg(2);
    s.aggregate = AggregateMode::Streaming;
    let mut f = mlp_cfg(2);
    f.aggregate = AggregateMode::Fused;
    let (rs, rf) = (run(s), run(f));
    assert_eq!(rs.rounds.len(), rf.rounds.len());
    for (a, b) in rs.rounds.iter().zip(&rf.rounds) {
        // identical wire traffic; numerics may differ only by summation
        // implementation, and on the native backend not even by that
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert!(
            (a.train_loss - b.train_loss).abs() <= 1e-4 * a.train_loss.abs().max(1.0),
            "round {}: {} vs {}",
            a.round,
            a.train_loss,
            b.train_loss
        );
    }
}

/// Semi-sync fixture: stall half the cohort hard enough to overshoot a
/// 30s budget by exactly two round-lengths (`t = 75s` against `T = 30s`
/// gives `s = ceil(45/30) = 2`), so `--staleness 2` banks the stragglers
/// while `--staleness 1` drops them as over-budget.
fn semisync_cfg(threads: usize, stall_p: f64, k: u32) -> RunConfig {
    let mut c = mlp_cfg(threads);
    c.sim_faults = FaultProfile::Stall { p: stall_p, secs: 75.0 };
    c.round.tolerance.round_timeout = Some(30.0);
    // Late members stay in the dispatched set but deliver no on-time
    // update, so the quorum floor must stay at 1 even for a round
    // where 9 of 10 members run late (f32 0.1 widens past 0.1, making
    // ceil(q·10) = 2 — 0.05 keeps the floor at ceil(0.5…) = 1).
    c.round.tolerance.quorum = 0.05;
    c.round.tolerance.staleness = k;
    c
}

#[test]
fn staleness_matrix_is_engine_invariant() {
    // The bounded-staleness fold must be bit-identical between the
    // fully serial reference engine and the maximally parallel narrow
    // path, for every k — the banked-update fold is keyed by
    // (round, client id), never by arrival order.
    for k in [0u32, 1, 2] {
        let mut serial = semisync_cfg(1, 0.5, k);
        serial.agg_shards = 1;
        serial.eval_threads = 1;
        serial.round.pipeline.fold_overlap = false;
        serial.round.pipeline.codec = CodecMode::Reference;
        let mut parallel = semisync_cfg(4, 0.5, k);
        parallel.agg_shards = 3;
        parallel.eval_threads = 2;
        parallel.round.pipeline.fold_overlap = true;
        parallel.round.pipeline.decode_buffers = 2;
        parallel.round.pipeline.codec = CodecMode::Narrow;
        let (rs, rp) = (run(serial), run(parallel));
        assert_reports_identical(&rs, &rp, &format!("staleness={k}: serial-ref vs parallel-narrow"));
        let folded: u32 = rs.rounds.iter().map(|r| r.stale_folded).sum();
        let dropped: u32 = rs.rounds.iter().map(|r| r.stale_dropped).sum();
        let failed: u32 = rs.rounds.iter().map(|r| r.failed).sum();
        match k {
            0 => {
                // Strict synchronous: the tolerant drain discards late
                // replies without banking or counting them.
                assert_eq!(folded, 0, "k=0 must not fold stale updates");
                assert_eq!(dropped, 0, "k=0 must not count stale drops");
                assert!(failed > 0, "stall:0.5:75 against 30s must time someone out");
            }
            1 => {
                // Every overshoot is s=2 > k: counted as dropped, never folded.
                assert_eq!(folded, 0, "k=1 must not fold s=2 stragglers");
                assert!(dropped > 0, "k=1 must count s=2 stragglers as dropped");
            }
            _ => {
                // s=2 <= k: stragglers bank and fold two rounds later.
                assert!(folded > 0, "k=2 must fold banked stragglers");
                assert_eq!(dropped, 0, "k=2 admits every s=2 straggler");
            }
        }
    }
}

#[test]
fn staleness_is_inert_without_late_updates() {
    // A nonzero staleness bound with a fault-free cohort must change
    // nothing: no banked updates means every round takes the exact
    // strict-synchronous arithmetic path.
    let knobs = |k: u32| {
        let mut c = mlp_cfg(2);
        c.round.tolerance.quorum = 0.5; // quorum mode: staleness is legal
        c.round.tolerance.staleness = k;
        c
    };
    let strict = run(knobs(0));
    let semisync = run(knobs(2));
    assert_reports_identical(&strict, &semisync, "k=0 vs inert k=2");
    assert!(semisync.rounds.iter().all(|r| r.stale_folded == 0 && r.stale_dropped == 0));
}

/// Closed-loop fixture: a per-round uplink cap of ~2 bits/element
/// across the 10-client cohort under an 8-bit fixed policy (so the
/// budget clamp actually binds), plus a quantized downlink.  Both
/// knobs require error feedback.
fn budget_cfg(threads: usize, bit_budget: u64, downlink_bits: u32) -> RunConfig {
    let mut c = mlp_cfg(threads);
    c.policy = PolicyConfig::Fixed { bits: 8 };
    c.error_feedback = true;
    c.round.budget.bit_budget = bit_budget;
    c.round.budget.downlink_bits = downlink_bits;
    c
}

/// ~2 bits/element/client across the builtin 10-client mlp cohort
/// (d = 101770).
const MLP_D: u64 = 101_770;
const MLP_CAP: u64 = 10 * MLP_D * 2;

#[test]
fn budget_and_downlink_are_deterministic_across_the_knob_matrix() {
    // The tentpole acceptance matrix: --bit-budget x --downlink-bits
    // crossed against threads / shards / fold overlap / decode buffers
    // / codec path / fanout / participation.  Budgets derive only from
    // seed-pure arena flags and the controller's own ledger, and the
    // downlink replica chain is a pure function of the run seed — so
    // the all-serial reference-codec run must be bit-identical to the
    // maximally parallel narrow-codec run in every cell, including
    // params_hash and both downlink ledger columns.
    for &(fanout, participation) in &[(0u32, 1.0f32), (0, 0.5), (2, 1.0), (4, 0.5)] {
        let knobs = |threads: usize| {
            let mut c = budget_cfg(threads, MLP_CAP, 4);
            c.round.topology.fanout = fanout;
            c.round.cohort.participation = participation;
            c
        };
        let serial = {
            let mut c = knobs(1);
            c.agg_shards = 1;
            c.eval_threads = 1;
            c.round.pipeline.fold_overlap = false;
            c.round.pipeline.codec = CodecMode::Reference;
            c
        };
        let base = run(serial);
        let parallel = {
            let mut c = knobs(4);
            c.agg_shards = 5;
            c.eval_threads = 3;
            c.round.pipeline.fold_overlap = true;
            c.round.pipeline.decode_buffers = 2;
            c.round.pipeline.codec = CodecMode::Narrow;
            c
        };
        assert_reports_identical(
            &base,
            &run(parallel),
            &format!(
                "budget+downlink fanout={fanout} participation={participation}: \
                 serial-ref vs parallel-narrow"
            ),
        );
        // The ledger must actually be charging the quantized chain:
        // round 0 is the full fp32 init, later rounds the ~4-bit delta.
        let r0 = &base.rounds[0];
        assert_eq!(
            r0.downlink_bits,
            r0.selected as u64 * MLP_D * 32,
            "fanout={fanout} p={participation}: init round is a full fp32 broadcast"
        );
        for r in &base.rounds[1..] {
            // A resampled cohort can pull in leaves that missed the
            // previous round; those resync at full fp32, so the strict
            // undercut is only guaranteed with everyone in every round.
            if participation == 1.0 {
                assert!(
                    r.downlink_bits < r.selected as u64 * MLP_D * 32,
                    "fanout={fanout} round {}: quantized delta {} must undercut \
                     the fp32 cost",
                    r.round,
                    r.downlink_bits
                );
            } else {
                assert!(
                    r.downlink_bits <= r.selected as u64 * MLP_D * 32,
                    "fanout={fanout} p={participation} round {}: ledger {} above \
                     the fp32 ceiling",
                    r.round,
                    r.downlink_bits
                );
            }
        }
    }
}

#[test]
fn budget_cap_bounds_the_uplink_ledger() {
    // The controller's allocation is a hard per-round cap on payload
    // bits; the wire adds only the fixed per-segment headers.  An
    // 8-bit policy without the cap must exceed it; with the cap every
    // round must fit under cap + header overhead, and the whole run
    // must ship fewer uplink bits.
    let capped = run(budget_cfg(2, MLP_CAP, 0));
    let free = run(budget_cfg(2, 0, 0));
    // mlp manifest: 4 segments, 88-bit header per segment per client,
    // plus up to 7 bits of byte padding per packed segment — the wire
    // ledger counts whole payload bytes.
    let header_slack = 10 * 4 * (88u64 + 7);
    for r in &capped.rounds {
        assert!(
            r.uplink_bits <= MLP_CAP + header_slack,
            "round {}: uplink {} exceeds cap {} + headers {}",
            r.round,
            r.uplink_bits,
            MLP_CAP,
            header_slack
        );
    }
    assert!(
        capped.rounds.last().unwrap().cum_uplink_bits
            < free.rounds.last().unwrap().cum_uplink_bits,
        "the cap must shrink the uplink ledger vs the uncapped 8-bit policy"
    );
}

#[test]
fn downlink_off_and_fp32_ledger_train_identically() {
    // --downlink-bits 32 is a pure ledger change: the broadcast is the
    // same fp32 `Arc<[f32]>` either way, so every column except the
    // two downlink counters must be bit-identical — and those must be
    // exactly n * d * 32 per round.
    let off = run(budget_cfg(2, 0, 0));
    let ledger = run(budget_cfg(2, 0, 32));
    assert_eq!(off.params_hash, ledger.params_hash, "b=32 must not touch training");
    assert_eq!(off.rounds.len(), ledger.rounds.len());
    for (a, b) in off.rounds.iter().zip(&ledger.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "r{}", a.round);
        assert_eq!(a.downlink_bits, 0, "off: nothing counted");
        assert_eq!(
            b.downlink_bits,
            b.selected as u64 * MLP_D * 32,
            "r{}: fp32 ledger counts every dispatched leaf",
            b.round
        );
    }
}

#[test]
fn quantized_downlink_undercuts_the_fp32_ledger() {
    // The point of the feature: the same run with a 4-bit downlink
    // must ship fewer broadcast bits than the fp32 ledger counts,
    // while every run stays internally deterministic (covered above).
    let fp32 = run(budget_cfg(2, 0, 32));
    let q4 = run(budget_cfg(2, 0, 4));
    assert!(
        q4.rounds.last().unwrap().cum_downlink_bits
            < fp32.rounds.last().unwrap().cum_downlink_bits,
        "4-bit downlink {} must undercut fp32 {}",
        q4.rounds.last().unwrap().cum_downlink_bits,
        fp32.rounds.last().unwrap().cum_downlink_bits
    );
}

#[test]
fn budget_and_downlink_compose_with_faults_and_staleness() {
    // The harshest composition: stall faults + semi-sync staleness +
    // budget + quantized downlink.  Late and failed members drive the
    // controller's flag inputs and the downlink sync map (failed
    // members are never dispatched; late ones are), so this exercises
    // the full closed loop — and it must still be engine-invariant.
    let knobs = |threads: usize| {
        let mut c = semisync_cfg(threads, 0.5, 2);
        c.rounds = 6;
        c.policy = PolicyConfig::Fixed { bits: 8 };
        c.error_feedback = true;
        c.round.budget.bit_budget = MLP_CAP;
        c.round.budget.downlink_bits = 4;
        c
    };
    let serial = {
        let mut c = knobs(1);
        c.agg_shards = 1;
        c.eval_threads = 1;
        c.round.pipeline.fold_overlap = false;
        c.round.pipeline.codec = CodecMode::Reference;
        c
    };
    let base = run(serial);
    let folded: u32 = base.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded > 0, "the fixture must actually produce late members");
    let mut parallel = knobs(4);
    parallel.agg_shards = 3;
    parallel.eval_threads = 2;
    parallel.round.pipeline.fold_overlap = true;
    parallel.round.pipeline.decode_buffers = 2;
    parallel.round.pipeline.codec = CodecMode::Narrow;
    assert_reports_identical(
        &base,
        &run(parallel),
        "budget+downlink+stall+staleness: serial-ref vs parallel-narrow",
    );
}

#[test]
fn semisync_beats_strict_sync_on_simulated_makespan() {
    // With zero base latency an on-time member costs ~0s, a timed-out
    // member charges the full 30s budget, and a banked late member
    // charges nothing in the round it missed — so accepting stragglers
    // must strictly shrink the summed simulated makespan.
    let strict = run(semisync_cfg(2, 0.3, 0));
    let semisync = run(semisync_cfg(2, 0.3, 2));
    assert_eq!(strict.rounds.len(), semisync.rounds.len());
    let span = |r: &RunReport| r.rounds.iter().map(|x| x.sim_makespan_secs).sum::<f64>();
    assert!(
        span(&semisync) < span(&strict),
        "semi-sync makespan {} must beat strict-sync {}",
        span(&semisync),
        span(&strict)
    );
    let folded: u32 = semisync.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded > 0, "the makespan win must come from folded stragglers");
}
