//! Property layer for the quantized downlink codec
//! (`codec::encode_downlink` / `codec::apply_downlink`): the fused
//! SWAR encode is checked code-for-code against a scalar reference
//! oracle over random manifests, widths and seeds; the server-side
//! error-feedback residual must be *bitwise* the quantization error;
//! and the whole delta chain must be a pure function of its seed.
//!
//! These are the wire-level guarantees the round engine's downlink
//! integration leans on — the session-level counterparts (replica ==
//! broadcast across topologies, ledger monotonicity) live in
//! `parallel_determinism.rs` and `integration.rs`.

use std::collections::BTreeMap;

use feddq::coordinator::codec;
use feddq::quant::math;
use feddq::runtime::{ModelManifest, Segment};
use feddq::util::prop::{check, Gen};
use feddq::util::rng::Rng;
use feddq::wire::bitpack::BitReader;
use feddq::wire::swar;

/// Random segmented manifest: 1..=4 segments of 1..=48 elements.  Only
/// the quantization-relevant fields matter to the codec; the training
/// fields are inert placeholders.
fn manifest(g: &mut Gen) -> ModelManifest {
    let nseg = g.size(1, 4);
    let mut segments = Vec::with_capacity(nseg);
    let mut offset = 0usize;
    for l in 0..nseg {
        let size = g.size(1, 48);
        segments.push(Segment {
            name: format!("s{l}"),
            offset,
            size,
            shape: vec![size],
        });
        offset += size;
    }
    ModelManifest {
        name: "downlink-prop".into(),
        d: offset,
        segments,
        input_shape: vec![1],
        classes: 2,
        tau: 1,
        batch: 1,
        eval_batch: 1,
        n_clients: 2,
        files: BTreeMap::new(),
    }
}

/// Per-segment (min, range) with the exact envelope scan the encoder
/// uses (min/max fold, range clamped non-negative).
fn envelope(mm: &ModelManifest, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    mm.segments
        .iter()
        .map(|seg| {
            let s = &x[seg.offset..seg.offset + seg.size];
            let mn = s.iter().fold(f32::INFINITY, |a, &v| a.min(v));
            let mx = s.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            (mn, (mx - mn).max(0.0))
        })
        .unzip()
}

/// Scalar reference oracle: the quantize executable's per-element
/// contract, straight from `kernels/ref.py` —
/// `c = clamp(floor((x - min) * sinv + u), 0, s)` with `u ~ U[0,1)`
/// drawn from `Rng::new(seed)` in flat element order — plus the EF
/// residual expression `x - (min + c * step)`.  Returns (codes,
/// residual, per-segment min, per-segment step).
fn scalar_oracle(
    mm: &ModelManifest,
    x: &[f32],
    bits: u32,
    seed: u32,
) -> (Vec<u16>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mins, ranges) = envelope(mm, x);
    let s = math::max_level_for_bits(bits).max(1) as f32;
    let mut rng = Rng::new(seed as u64);
    let mut codes = vec![0u16; mm.d];
    let mut residual = vec![0f32; mm.d];
    let mut steps = Vec::with_capacity(mm.segments.len());
    for (l, seg) in mm.segments.iter().enumerate() {
        // QuantPlan's degenerate-range guard: below eps the segment
        // collapses to its min (sinv = step = 0).
        let (sinv, step) = if ranges[l] > 1e-12 && ranges[l].is_finite() {
            (s / ranges[l], ranges[l] / s)
        } else {
            (0.0, 0.0)
        };
        steps.push(step);
        for j in seg.offset..seg.offset + seg.size {
            let u = rng.next_f32();
            let y = ((x[j] - mins[l]) * sinv + u).floor();
            let c = y.clamp(0.0, s);
            codes[j] = c as u32 as u16;
            residual[j] = x[j] - (mins[l] + c * step);
        }
    }
    (codes, residual, mins, steps)
}

/// Unpack a downlink payload back to per-element codes (test-side
/// decoder, independent of `apply_downlink`'s arithmetic).
fn unpack_codes(mm: &ModelManifest, dl: &feddq::wire::messages::DownlinkDelta) -> Vec<u16> {
    let mut r = BitReader::new(&dl.payload);
    let mut out: Vec<u16> = Vec::with_capacity(mm.d);
    for (seg, h) in mm.segments.iter().zip(&dl.segments) {
        swar::unpack_u16(&mut r, &mut out, seg.size, h.bits as u32)
            .expect("payload long enough for its own headers");
    }
    out
}

#[test]
fn prop_fused_downlink_matches_scalar_oracle() {
    check("fused downlink == scalar oracle", 300, |g| {
        let mm = manifest(g);
        let bits = g.size(1, 16) as u32;
        let seed = g.rng.next_u64() as u32;
        // Wide-magnitude values (zeros, uniforms, 2^±20 scales,
        // normals) — the regime where a fused/scalar divergence in
        // rounding or clamping would show.
        let x: Vec<f32> = g.vec_of(mm.d, |g| g.f32_wide());
        let (want_codes, want_res, want_mins, want_steps) = scalar_oracle(&mm, &x, bits, seed);

        // x enters as (params - replica) + residual with replica and
        // residual zero, so the quantizer input is exactly `x`.
        let mut residual = vec![0f32; mm.d];
        let dl = codec::encode_downlink(&mm, bits, &x, &vec![0f32; mm.d], &mut residual, seed)
            .map_err(|e| format!("encode failed: {e:#}"))?;

        let payload_bits: usize = mm.segments.iter().map(|s| s.size * bits as usize).sum();
        if dl.payload.len() != (payload_bits + 7) / 8 {
            return Err(format!(
                "payload {} bytes, want exactly {}",
                dl.payload.len(),
                (payload_bits + 7) / 8
            ));
        }
        for (l, h) in dl.segments.iter().enumerate() {
            if h.bits as u32 != bits {
                return Err(format!("segment {l} header width {} != {bits}", h.bits));
            }
            if h.min.to_bits() != want_mins[l].to_bits()
                || h.step.to_bits() != want_steps[l].to_bits()
            {
                return Err(format!("segment {l} header (min, step) mismatch"));
            }
        }
        let got_codes = unpack_codes(&mm, &dl);
        if got_codes != want_codes {
            return Err(format!(
                "codes diverge from scalar oracle (bits {bits}, d {})",
                mm.d
            ));
        }
        for j in 0..mm.d {
            if residual[j].to_bits() != want_res[j].to_bits() {
                return Err(format!(
                    "EF residual[{j}] = {} not bitwise {}",
                    residual[j], want_res[j]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decode_lands_within_one_step_and_residual_is_exact() {
    check("downlink round-trip error bound", 300, |g| {
        let mm = manifest(g);
        let bits = g.size(1, 16) as u32;
        let seed = g.rng.next_u64() as u32;
        // Tame values: the one-step bound below is the exact-arithmetic
        // guarantee plus a small float allowance, which wide 2^±20
        // magnitudes would need looser slack for (the oracle test above
        // covers those bit-exactly).
        let x: Vec<f32> = g.vec_of(mm.d, |g| g.f32(-2.0, 2.0));
        let mut residual = vec![0f32; mm.d];
        let dl = codec::encode_downlink(&mm, bits, &x, &vec![0f32; mm.d], &mut residual, seed)
            .map_err(|e| format!("encode failed: {e:#}"))?;
        let mut applied = vec![0f32; mm.d];
        codec::apply_downlink(&mm, &dl, &mut applied)
            .map_err(|e| format!("apply failed: {e:#}"))?;
        for (l, seg) in mm.segments.iter().enumerate() {
            let step = dl.segments[l].step;
            let bound = step * (1.0 + 1e-4) + 1e-6;
            for j in seg.offset..seg.offset + seg.size {
                let err = (x[j] - applied[j]).abs();
                if !(err <= bound) {
                    return Err(format!(
                        "element {j}: |x - decoded| = {err} > {bound} (step {step})"
                    ));
                }
                // The EF contract: what the wire lost is exactly what
                // the residual banked — nothing leaks out of the loop.
                if residual[j].to_bits() != (x[j] - applied[j]).to_bits() {
                    return Err(format!("residual[{j}] != x - decoded bitwise"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_delta_chain_is_a_pure_function_of_its_seed() {
    // Run a multi-round server-side chain (params drift, EF residual
    // carry, replica advanced by replaying the encoded wire) twice and
    // require bitwise-identical payloads and replicas — the property
    // the round engine's determinism contract inherits.
    check("downlink chain replays bitwise", 60, |g| {
        let mm = manifest(g);
        let bits = g.size(1, 8) as u32;
        let rounds = g.size(2, 5);
        let chain_seed = g.rng.next_u64();
        // Seed-pure params trajectory, shared by both replays.
        let mut traj = Rng::new(chain_seed);
        let mut params_by_round: Vec<Vec<f32>> = Vec::with_capacity(rounds);
        let mut p: Vec<f32> = (0..mm.d).map(|_| traj.next_f32() * 2.0 - 1.0).collect();
        for _ in 0..rounds {
            p.iter_mut().for_each(|v| *v += 0.05 * (traj.next_f32() - 0.5));
            params_by_round.push(p.clone());
        }
        let run = |tag: &str| -> Result<(Vec<Vec<u8>>, Vec<f32>), String> {
            let mut replica = params_by_round[0].clone(); // init round: full
            let mut residual = vec![0f32; mm.d];
            let mut rng = Rng::new(chain_seed).derive("server.downlink");
            let mut payloads = Vec::new();
            for params in &params_by_round[1..] {
                let seed = rng.next_u32();
                let dl =
                    codec::encode_downlink(&mm, bits, params, &replica, &mut residual, seed)
                        .map_err(|e| format!("{tag}: encode failed: {e:#}"))?;
                codec::apply_downlink(&mm, &dl, &mut replica)
                    .map_err(|e| format!("{tag}: apply failed: {e:#}"))?;
                if !replica.iter().all(|v| v.is_finite()) {
                    return Err(format!("{tag}: replica went non-finite"));
                }
                payloads.push(dl.payload);
            }
            Ok((payloads, replica))
        };
        let (pay_a, rep_a) = run("first")?;
        let (pay_b, rep_b) = run("second")?;
        if pay_a != pay_b {
            return Err("replayed chain produced different payloads".into());
        }
        let bits_a: Vec<u32> = rep_a.iter().map(|v| v.to_bits()).collect();
        let bits_b: Vec<u32> = rep_b.iter().map(|v| v.to_bits()).collect();
        if bits_a != bits_b {
            return Err("replayed chain produced different replicas".into());
        }
        Ok(())
    });
}

#[test]
fn prop_malformed_downlink_frames_err_and_never_panic() {
    // Truncated, oversized and bit-flipped-width frames must all come
    // back as Err from `apply_downlink` — a malicious or corrupt
    // broadcast must not be able to panic a worker.
    check("malformed downlink frames rejected", 200, |g| {
        let mm = manifest(g);
        let bits = g.size(1, 16) as u32;
        let seed = g.rng.next_u64() as u32;
        let x: Vec<f32> = g.vec_of(mm.d, |g| g.f32(-1.0, 1.0));
        let mut residual = vec![0f32; mm.d];
        let dl = codec::encode_downlink(&mm, bits, &x, &vec![0f32; mm.d], &mut residual, seed)
            .map_err(|e| format!("encode failed: {e:#}"))?;
        let mut out = vec![0f32; mm.d];

        if !dl.payload.is_empty() {
            let mut short = dl.clone();
            short.payload.pop();
            if codec::apply_downlink(&mm, &short, &mut out).is_ok() {
                return Err("truncated payload accepted".into());
            }
        }
        let mut long = dl.clone();
        long.payload.push(0);
        if codec::apply_downlink(&mm, &long, &mut out).is_ok() {
            return Err("oversized payload accepted".into());
        }
        let mut wide = dl.clone();
        let l = g.size(0, wide.segments.len() - 1);
        wide.segments[l].bits = *g.choose(&[0u8, 17, 32, 255]);
        if codec::apply_downlink(&mm, &wide, &mut out).is_ok() {
            return Err("out-of-range segment width accepted".into());
        }
        let mut fewer = dl.clone();
        fewer.segments.pop();
        if codec::apply_downlink(&mm, &fewer, &mut out).is_ok() {
            return Err("missing segment header accepted".into());
        }
        let mut short_replica = vec![0f32; mm.d - 1];
        if codec::apply_downlink(&mm, &dl, &mut short_replica).is_ok() {
            return Err("short replica accepted".into());
        }
        Ok(())
    });
}
