//! Integration tests over the model runtime: numerics, codec
//! round-trips through the actual executables, full sessions, and the
//! TCP topology.  They run against the AOT artifacts when `make
//! artifacts` has produced them (and the `pjrt` feature is enabled),
//! and against the built-in native MLP backend otherwise — the session,
//! codec and wire behavior under test is identical either way.

use feddq::config::RunConfig;
use feddq::coordinator::codec::{self, QuantPlan};
use feddq::coordinator::{topology, Session};
use feddq::data::{shard::Sharding, DatasetKind};
use feddq::quant::{math, PolicyConfig};
use feddq::runtime::Runtime;
use feddq::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new("artifacts").expect("runtime over artifacts or builtin manifest")
}

fn ramp(d: usize) -> Vec<f32> {
    (0..d)
        .map(|i| -2.0 + 5.0 * i as f32 / (d as f32 - 1.0))
        .collect()
}

#[test]
fn manifest_lists_expected_models() {
    let rt = runtime();
    // The built-in native manifest carries only the MLP benchmark; real
    // AOT artifacts must list the full model zoo.
    let expected: &[&str] = if rt.is_builtin() {
        &["mlp"]
    } else {
        &["mlp", "vanilla_cnn", "cnn4", "resnet18"]
    };
    for m in expected {
        assert!(rt.manifest.models.contains_key(*m), "{m} missing");
        rt.manifest.models[*m].validate().unwrap();
    }
}

#[test]
fn ranges_executable_matches_cpu_oracle() {
    let rt = runtime();
    let model = rt.load_model("mlp").unwrap();
    let delta = ramp(model.mm.d);
    let (mins, ranges) = model.ranges(&delta).unwrap();
    // oracle: direct slice min/max
    for (l, seg) in model.mm.segments.iter().enumerate() {
        let s = &delta[seg.offset..seg.offset + seg.size];
        let lo = s.iter().copied().fold(f32::INFINITY, f32::min);
        let hi = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!((mins[l] - lo).abs() < 1e-5, "seg {l} min");
        assert!((ranges[l] - (hi - lo)).abs() < 1e-5, "seg {l} range");
    }
}

#[test]
fn quantize_executable_codes_are_valid_and_unbiased_ish() {
    let rt = runtime();
    let model = rt.load_model("mlp").unwrap();
    let d = model.mm.d;
    let delta = ramp(d);
    let (mins, ranges) = model.ranges(&delta).unwrap();
    let levels: Vec<u32> = vec![255; model.mm.num_segments()];
    let plan = QuantPlan::new(&levels, &ranges);
    let codes = model
        .quantize(&delta, &mins, &plan.sinv, &plan.maxcode, 7)
        .unwrap();
    assert_eq!(codes.len(), d);
    // codes integral, within [0, s]; dequantization close to the input
    for (l, seg) in model.mm.segments.iter().enumerate() {
        let mut max_err = 0.0f32;
        for j in seg.offset..seg.offset + seg.size {
            let c = codes[j];
            assert_eq!(c, c.round(), "non-integral code at {j}");
            assert!((0.0..=255.0).contains(&c));
            let deq = mins[l] + c * plan.step[l];
            max_err = max_err.max((deq - delta[j]).abs());
        }
        // per-segment quantization error bounded by one step
        assert!(
            max_err <= plan.step[l] * 1.001 + 1e-6,
            "seg {l}: err {max_err} > step {}",
            plan.step[l]
        );
    }
}

#[test]
fn aggregate_executable_is_weighted_mean_of_dequants() {
    let rt = runtime();
    let model = rt.load_model("mlp").unwrap();
    let mm = &model.mm;
    let (n, d, l) = (mm.n_clients, mm.d, mm.num_segments());
    let mut rng = Rng::new(5);
    let codes: Vec<f32> = (0..n * d).map(|_| rng.below(16) as f32).collect();
    let mins: Vec<f32> = (0..n * l).map(|_| rng.next_f32() - 0.5).collect();
    let steps: Vec<f32> = (0..n * l).map(|_| rng.next_f32() * 0.01).collect();
    let mut weights: Vec<f32> = (0..n).map(|_| 0.1 + rng.next_f32()).collect();
    let sum: f32 = weights.iter().sum();
    weights.iter_mut().for_each(|w| *w /= sum);

    let got = model.aggregate(&codes, &mins, &steps, &weights).unwrap();

    // oracle in plain rust
    let mut want = vec![0.0f64; d];
    for i in 0..n {
        for (sl, seg) in mm.segments.iter().enumerate() {
            let (mn, st) = (mins[i * l + sl] as f64, steps[i * l + sl] as f64);
            for j in seg.offset..seg.offset + seg.size {
                want[j] += weights[i] as f64 * (codes[i * d + j] as f64 * st + mn);
            }
        }
    }
    for j in 0..d {
        assert!(
            (got[j] as f64 - want[j]).abs() < 1e-4,
            "elem {j}: {} vs {}",
            got[j],
            want[j]
        );
    }
}

#[test]
fn codec_roundtrip_through_real_quantizer() {
    // encode_quantized -> decode_update must reproduce codes/mins/steps
    // bit-exactly for real executable outputs.
    let rt = runtime();
    let model = rt.load_model("mlp").unwrap();
    let mm = &model.mm;
    let delta = ramp(mm.d);
    let (mins, ranges) = model.ranges(&delta).unwrap();
    let levels: Vec<u32> = (0..mm.num_segments())
        .map(|l| [1u32, 7, 255, 65535][l % 4])
        .collect();
    let plan = QuantPlan::new(&levels, &ranges);
    let codes = model
        .quantize(&delta, &mins, &plan.sinv, &plan.maxcode, 99)
        .unwrap();
    let (headers, payload) = codec::encode_quantized(mm, &plan, &mins, &codes);
    // wire size matches the analytic model
    let seg_sizes = mm.segment_sizes();
    let bits: Vec<u32> = levels.iter().map(|&s| math::bits_for_level(s)).collect();
    let payload_bits = math::update_payload_bits(&seg_sizes, &bits);
    assert_eq!(payload.len() as u64, (payload_bits + 7) / 8);
    let u = feddq::wire::messages::Update {
        round: 0,
        client_id: 0,
        num_samples: 1,
        train_loss: 0.0,
        segments: headers,
        payload,
    };
    let dec = codec::decode_update(mm, &u).unwrap();
    assert_eq!(dec.codes_f32(mm), codes);
    for l in 0..mm.num_segments() {
        assert_eq!(dec.mins[l], mins[l]);
        assert!((dec.steps[l] - plan.step[l]).abs() < 1e-12);
    }
}

fn tiny_cfg(policy: PolicyConfig) -> RunConfig {
    let mut cfg = RunConfig::default_for("mlp");
    cfg.rounds = 3;
    cfg.train_size = 600;
    cfg.test_size = 500; // one eval batch
    cfg.policy = policy;
    cfg
}

#[test]
fn session_runs_and_accounts_bits_feddq() {
    let mut session = Session::new(tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 })).unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.rounds.len(), 3);
    for r in &report.rounds {
        assert!(r.train_loss.is_finite());
        assert!(r.uplink_bits > 0);
        assert!(r.mean_bits >= 1.0 && r.mean_bits <= 16.0);
        assert!(r.mean_range > 0.0);
    }
    // cumulative bits strictly increasing
    assert!(report
        .rounds
        .windows(2)
        .all(|w| w[1].cum_uplink_bits > w[0].cum_uplink_bits));
}

#[test]
fn session_fp32_costs_32_bits_per_element() {
    let mut session = Session::new(tiny_cfg(PolicyConfig::Fp32)).unwrap();
    let report = session.run().unwrap();
    let r = &report.rounds[0];
    assert!((r.mean_bits - 32.0).abs() < 1e-6);
    let mm_d = session.manifest().d as u64;
    let l = session.manifest().num_segments() as u64;
    let n = session.manifest().n_clients as u64;
    let expect = n * (mm_d * 32 + l * math::SEGMENT_HEADER_BITS);
    assert_eq!(r.uplink_bits, expect);
}

#[test]
fn session_fixed_bits_match_policy() {
    let mut session = Session::new(tiny_cfg(PolicyConfig::Fixed { bits: 4 })).unwrap();
    let report = session.run().unwrap();
    assert!((report.rounds[0].mean_bits - 4.0).abs() < 1e-6);
}

#[test]
fn feddq_bits_descend_over_training() {
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg.rounds = 8;
    let mut session = Session::new(cfg).unwrap();
    let report = session.run().unwrap();
    let first = report.rounds.first().unwrap().mean_bits;
    let last = report.rounds.last().unwrap().mean_bits;
    assert!(
        last < first,
        "FedDQ bits should descend: first {first}, last {last}"
    );
}

#[test]
fn runs_are_deterministic_given_seed() {
    let r1 = Session::new(tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 }))
        .unwrap()
        .run()
        .unwrap();
    let r2 = Session::new(tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 }))
        .unwrap()
        .run()
        .unwrap();
    for (a, b) in r1.rounds.iter().zip(&r2.rounds) {
        assert_eq!(a.train_loss, b.train_loss);
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.test_accuracy, b.test_accuracy);
    }
}

#[test]
fn dirichlet_sharding_session_runs() {
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg.sharding = Sharding::Dirichlet { alpha: 0.3 };
    let report = Session::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 3);
}

#[test]
fn dataset_model_mismatch_rejected() {
    let mut cfg = tiny_cfg(PolicyConfig::Fp32);
    cfg.dataset = DatasetKind::Cifar10; // mlp expects 28x28x1
    assert!(Session::new(cfg).is_err());
}

#[test]
fn tcp_topology_matches_nothing_burns() {
    // Serve a 2-round run over real TCP with in-process worker threads
    // (each worker gets its own PJRT runtime, as in multi-process mode).
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg.rounds = 2;
    let addr = "127.0.0.1:17871";
    let n = 10;
    let workers: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.to_string();
            // the worker retries the connect internally (bounded backoff),
            // so racing the server's bind() needs no loop here
            std::thread::spawn(move || {
                topology::worker(&addr, id, "artifacts").unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
            })
        })
        .collect();
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(report.rounds.len(), 2);

    // Same run in-process must produce identical losses and bit volumes
    // (the data pipeline and quantizer streams are seed-deterministic).
    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg2.rounds = 2;
    let local = Session::new(cfg2).unwrap().run().unwrap();
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.train_loss, b.train_loss, "tcp vs local train loss");
        assert_eq!(a.uplink_bits, b.uplink_bits, "tcp vs local bits");
    }
}

#[test]
fn sampled_session_scales_ledger_to_cohort_and_roundtrips_json() {
    // fp32 makes the ledger exactly predictable: each participating
    // client costs d*32 + L*88 bits, so a 0.3-participation round must
    // bill exactly 3 clients (builtin cohort = 10), not 10.
    let mut cfg = tiny_cfg(PolicyConfig::Fp32);
    cfg.rounds = 4;
    cfg.round.cohort.participation = 0.3;
    let mut session = Session::new(cfg).unwrap();
    let d = session.manifest().d as u64;
    let l = session.manifest().num_segments() as u64;
    let report = session.run().unwrap();
    let per_client = d * 32 + l * math::SEGMENT_HEADER_BITS;
    for r in &report.rounds {
        assert_eq!(r.selected, 3, "round {}", r.round);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.uplink_bits, 3 * per_client, "round {}", r.round);
    }
    // the report's JSON schema round-trips with the scheduler fields
    let text = report.to_json().to_string_pretty();
    let back = feddq::metrics::RunReport::from_json_str(&text).unwrap();
    assert_eq!(back.params_hash, report.params_hash);
    assert_eq!(back.rounds.len(), report.rounds.len());
    for (a, b) in report.rounds.iter().zip(&back.rounds) {
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.uplink_bits, b.uplink_bits);
        assert_eq!(a.cum_uplink_bits, b.cum_uplink_bits);
    }
}

#[test]
fn sampled_tcp_topology_matches_sampled_local_run() {
    // Partial participation over real sockets: unselected workers just
    // block until a later cohort (or Shutdown) — and the whole run must
    // agree with the in-process session bit for bit on losses and the
    // ledger (same seed => same cohorts => same everything).
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 3;
        cfg.round.cohort.participation = 0.5;
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17873";
    let n = 10;
    let workers: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                topology::worker(&addr, id, "artifacts").unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
            })
        })
        .collect();
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, 5, "round {}", a.round);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.train_loss, b.train_loss, "tcp vs local train loss");
        assert_eq!(a.uplink_bits, b.uplink_bits, "tcp vs local bits");
    }
    assert_eq!(report.params_hash, local.params_hash, "tcp vs local params");
}

#[test]
fn tcp_run_survives_a_worker_crash_and_rejoin() {
    use feddq::wire::messages::Message;
    use feddq::wire::transport::{TcpTransport, Transport};
    use std::sync::mpsc;
    use std::time::Duration;

    // Quorum aggregation over real sockets: one worker crashes before
    // serving a single round, the other nine carry the run, and a
    // restarted worker re-attaches mid-run via the rejoin accept loop.
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg.rounds = 8;
    cfg.round.tolerance.quorum = 0.5;
    cfg.round.tolerance.round_timeout = Some(30.0);
    let addr = "127.0.0.1:17875";
    let n = 10;

    // Worker 0 joins and completes the ready handshake, then dies: the
    // server sees a healthy cohort member whose socket breaks at round 0.
    let mortal = {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            let mut t =
                TcpTransport::connect_retry(&addr, 100, Duration::from_millis(50)).unwrap();
            t.send(&Message::Join { client_id: 0, num_samples: None }).unwrap();
            match t.recv().unwrap() {
                Message::Welcome { client_id, .. } => assert_eq!(client_id, 0),
                other => panic!("expected Welcome, got {other:?}"),
            }
            t.send(&Message::Join { client_id: 0, num_samples: Some(60) }).unwrap();
            // dropping the transport closes the socket: a crash, as far
            // as the server can tell
        })
    };
    let healthy: Vec<_> = (1..n)
        .map(|id| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                topology::worker(&addr, id, "artifacts")
                    .unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
            })
        })
        .collect();

    // Restart worker 0 once the first round's record lands; it rejoins
    // the run in progress and serves whatever rounds remain.
    let (round0_tx, round0_rx) = mpsc::channel::<()>();
    let reborn = {
        let addr = addr.to_string();
        std::thread::spawn(move || {
            round0_rx.recv().unwrap();
            topology::worker(&addr, 0, "artifacts")
                .unwrap_or_else(|e| panic!("rejoined worker: {e:#}"))
        })
    };
    let mut signaled = false;
    let report = topology::serve(&cfg, addr, |_, _| {
        if !signaled {
            signaled = true;
            round0_tx.send(()).unwrap();
        }
    })
    .unwrap();
    mortal.join().unwrap();
    // The reborn worker only exits on Shutdown, which the server can
    // only deliver over the re-attached socket — joining the thread is
    // itself proof the rejoin path worked end to end.
    reborn.join().unwrap();
    for w in healthy {
        w.join().unwrap();
    }

    assert_eq!(report.rounds.len(), 8, "quorum run must complete every round");
    assert_eq!(report.rounds[0].failed, 1, "round 0 loses exactly the crashed worker");
    let failed: u32 = report.rounds.iter().map(|r| r.failed).sum();
    let rejoined: u32 = report.rounds.iter().map(|r| r.rejoined).sum();
    assert!(failed >= 1, "the crashed worker must be recorded as failed");
    assert!(rejoined >= 1, "the restarted worker must be recorded as rejoined, got {rejoined}");
}

/// Spawn a full aggregation tree for `serve_addr`: one aggregator
/// thread per subtree root (`0, f, 2f, ...` over `n` leaves) listening
/// on consecutive ports from `agg_base_port`, plus one worker thread
/// per leaf connecting to its subtree's aggregator.  Returns the join
/// handles (aggregators first, then workers).
fn spawn_tree(
    serve_addr: &str,
    agg_base_port: u16,
    n: u32,
    fanout: u32,
) -> Vec<std::thread::JoinHandle<()>> {
    let mut handles = Vec::new();
    for (t, lo) in (0..n).step_by(fanout as usize).enumerate() {
        let upstream = serve_addr.to_string();
        let addr = format!("127.0.0.1:{}", agg_base_port + t as u16);
        handles.push(std::thread::spawn(move || {
            topology::aggregate(&upstream, &addr, lo, fanout, "artifacts")
                .unwrap_or_else(|e| panic!("aggregator {lo}: {e:#}"))
        }));
    }
    for id in 0..n {
        let addr = format!("127.0.0.1:{}", agg_base_port + (id / fanout) as u16);
        handles.push(std::thread::spawn(move || {
            topology::worker(&addr, id, "artifacts")
                .unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
        }));
    }
    handles
}

#[test]
fn tcp_tree_topology_matches_virtual_grouped_local_run() {
    // A real two-tier tree (10 leaves -> 5 aggregator processes ->
    // server) must be bit-identical — params hash included — to the
    // in-process session with the same fanout, whose server applies
    // the identical grouping virtually through codec::fold_partial.
    // The grouping *defines* the canonical fold order, so the wire and
    // virtual paths fold the exact same f32 sequence.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 3;
        cfg.round.topology.fanout = 2;
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17879";
    let tree = spawn_tree(addr, 17901, 10, 2);
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for h in tree {
        h.join().unwrap();
    }
    assert!(report.label.ends_with("-tcp-tree"), "{}", report.label);
    assert_eq!(report.rounds.len(), 3);

    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, 10, "round {}", a.round);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.agg_depth, 2, "one aggregator tier above the leaves");
        assert_eq!(a.agg_depth, b.agg_depth);
        assert_eq!(a.train_loss, b.train_loss, "tree vs virtual train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs virtual bits r{}", a.round);
        assert_eq!(a.test_accuracy.is_nan(), b.test_accuracy.is_nan());
        if !a.test_accuracy.is_nan() {
            assert_eq!(a.test_accuracy, b.test_accuracy);
        }
        // both sides learn the same leaf counts into the arena
        assert!(a.client_state_bytes > 0);
        assert_eq!(a.client_state_bytes, b.client_state_bytes);
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs virtual params");

    // The leaf ledger charges real client uplinks, not the fp32
    // pseudo-update frames: the flat run's bit ledger must agree
    // round for round even though its fold order (and hence its
    // params hash) legitimately differs.
    let mut cfg3 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg3.rounds = 3;
    let flat = Session::new(cfg3).unwrap().run().unwrap();
    assert_eq!(report.rounds[0].uplink_bits, flat.rounds[0].uplink_bits);
    assert_eq!(flat.rounds[0].agg_depth, 0, "flat topology reports depth 0");
}

#[test]
fn tcp_tree_composes_with_sampling_quorum_staleness_and_reference_codec() {
    // The whole RoundPolicy surface at once, over the tree: sampled
    // leaf cohorts (only subtrees owning selected leaves hear the
    // broadcast), tolerant receive (quorum + timeout + staleness
    // armed), and the scalar reference codec in the folds — still
    // bit-identical to the virtually-grouped in-process run.
    use feddq::config::CodecMode;
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 3;
        cfg.round.topology.fanout = 2;
        cfg.round.cohort.participation = 0.5;
        cfg.round.tolerance.quorum = 0.5;
        cfg.round.tolerance.round_timeout = Some(30.0);
        cfg.round.tolerance.staleness = 2;
        cfg.round.pipeline.codec = CodecMode::Reference;
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17907";
    let tree = spawn_tree(addr, 17911, 10, 2);
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for h in tree {
        h.join().unwrap();
    }
    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, 5, "round {}: half the 10 leaves", a.round);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.failed, 0);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.stale_folded, b.stale_folded);
        assert_eq!(a.stale_dropped, b.stale_dropped);
        assert_eq!(a.agg_depth, 2);
        assert_eq!(a.agg_depth, b.agg_depth);
        assert_eq!(a.train_loss, b.train_loss, "tree vs virtual train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs virtual bits r{}", a.round);
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs virtual params");
}

#[test]
fn tcp_tree_with_sim_crash_faults_matches_virtual_grouped_local_run() {
    use feddq::sim::faults::FaultProfile;
    // The faults x topology composition over real sockets: crash draws
    // are pure in (seed, leaf id, round), the failed leaves vanish from
    // the broadcast's cohort routing field (their aggregator never
    // relays to them), and the leaf-granular quorum judges the
    // survivors — so the whole run must stay bit-identical to the
    // in-process session with the same knobs, fault columns included.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 5;
        cfg.round.topology.fanout = 2;
        cfg.sim_faults = FaultProfile::Crash { p: 0.3 };
        // sim-failed leaves are excluded before dispatch, so the
        // leaf-granular floor ranges over the *surviving* cohort and
        // every survivor reports — the worst round at this seed keeps
        // 5 of 10 leaves and still clears ceil(0.5 * 5) = 3
        cfg.round.tolerance.quorum = 0.5;
        cfg.round.tolerance.round_timeout = Some(30.0);
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17920";
    let tree = spawn_tree(addr, 17921, 10, 2);
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for h in tree {
        h.join().unwrap();
    }
    assert_eq!(report.rounds.len(), 5);
    let total_failed: u32 = report.rounds.iter().map(|r| r.failed).sum();
    assert!(total_failed > 0, "crash:0.3 over 5 rounds of 10 leaves must fail someone");

    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, 10, "round {}: failed members still count as selected", a.round);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.failed, b.failed, "round {}: failed set is seed-pure", a.round);
        assert_eq!(a.rejoined, 0, "round {}: simulated crashes never rejoin", a.round);
        assert_eq!(a.subtree_failed, 0, "round {}: sim faults kill leaves, not subtrees", a.round);
        assert_eq!(a.subtree_failed, b.subtree_failed);
        assert_eq!(a.degraded, 0, "round {}", a.round);
        assert_eq!(a.degraded, b.degraded);
        assert_eq!(a.agg_depth, 2);
        assert_eq!(a.agg_depth, b.agg_depth);
        assert_eq!(a.train_loss, b.train_loss, "tree vs virtual train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs virtual bits r{}", a.round);
        assert_eq!(a.client_state_bytes, b.client_state_bytes, "round {}", a.round);
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs virtual params");
}

#[test]
fn tcp_tree_semisync_forwards_straggler_relays_raw_and_matches_local() {
    use feddq::sim::faults::FaultProfile;
    // Bounded staleness under the tree, over real sockets: a late
    // leaf's update is relayed to its aggregator, forwarded upstream
    // RAW (never folded into the partial), banked by the root at
    // dispatch and folded with discounted weight at its due round —
    // the identical object, bank and ledger the flat topology and the
    // in-process virtual grouping produce.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 4;
        cfg.round.topology.fanout = 2;
        cfg.sim_faults = FaultProfile::Stall { p: 0.5, secs: 75.0 };
        cfg.round.tolerance.round_timeout = Some(30.0);
        // see semisync_tcp_run_banks_and_folds_stragglers_like_local
        // for why the floor must stay at ceil(0.05 * n) = 1
        cfg.round.tolerance.quorum = 0.05;
        cfg.round.tolerance.staleness = 2;
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17926";
    let tree = spawn_tree(addr, 17927, 10, 2);
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for h in tree {
        h.join().unwrap();
    }
    let folded: u32 = report.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded >= 1, "stall:0.5:75 under --staleness 2 must fold a straggler");

    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, b.selected, "round {}", a.round);
        assert_eq!(a.failed, b.failed, "round {}", a.round);
        assert_eq!(a.stale_folded, b.stale_folded, "round {}", a.round);
        assert_eq!(a.stale_dropped, b.stale_dropped, "round {}", a.round);
        assert_eq!(a.subtree_failed, b.subtree_failed, "round {}", a.round);
        assert_eq!(a.degraded, b.degraded, "round {}", a.round);
        assert_eq!(a.train_loss, b.train_loss, "tree vs virtual train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs virtual bits r{}", a.round);
        assert_eq!(a.client_state_bytes, b.client_state_bytes, "round {}", a.round);
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs virtual params");
}

/// A stand-in aggregator for crash tests: it completes the aggregator
/// setup protocol end to end — join upstream, adopt its leaves (relaying
/// the run config, optionally stamped with a `fallback_addr` like the
/// real `feddq aggregate` does), collect their ready acks and ack
/// readiness upstream — then drops its listener and every socket at
/// once.  As far as the server and the subtree's leaves can tell, the
/// aggregator process was kill -9'd just before round 0.  Sends on the
/// returned channel after the sockets are gone (so a restarted
/// aggregator can safely rebind the address).
fn mortal_aggregator(
    serve_addr: &str,
    agg_addr: &str,
    lo: u32,
    fanout: u32,
    stamp_fallback: bool,
) -> (std::thread::JoinHandle<()>, std::sync::mpsc::Receiver<()>) {
    use feddq::wire::messages::Message;
    use feddq::wire::transport::{TcpTransport, Transport};
    let (died_tx, died_rx) = std::sync::mpsc::channel::<()>();
    let (serve_addr, agg_addr) = (serve_addr.to_string(), agg_addr.to_string());
    let handle = std::thread::spawn(move || {
        let listener = std::net::TcpListener::bind(&agg_addr).unwrap();
        let mut up =
            TcpTransport::connect_retry(&serve_addr, 100, std::time::Duration::from_millis(50))
                .unwrap();
        up.send(&Message::Join { client_id: lo, num_samples: None }).unwrap();
        let config_json = match up.recv().unwrap() {
            Message::Welcome { client_id, config_json, .. } => {
                assert_eq!(client_id, lo);
                config_json
            }
            other => panic!("expected Welcome, got {other:?}"),
        };
        // the real aggregator stamps its upstream into the relayed
        // config so its orphaned leaves can degrade to the root
        let leaf_config = if stamp_fallback {
            assert!(config_json.starts_with('{'), "compact config JSON");
            format!("{{\"fallback_addr\":\"{serve_addr}\",{}", &config_json[1..])
        } else {
            config_json
        };
        let mut children = Vec::new();
        for _ in 0..fanout {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let id = match t.recv().unwrap() {
                Message::Join { client_id, .. } => client_id,
                other => panic!("expected Join, got {other:?}"),
            };
            t.send(&Message::Welcome {
                client_id: id,
                config_json: leaf_config.clone(),
                round: None,
            })
            .unwrap();
            children.push((id, t));
        }
        let mut total = 0u32;
        for (id, t) in children.iter_mut() {
            match t.recv().unwrap() {
                Message::Join { client_id, num_samples } => {
                    assert_eq!(client_id, *id);
                    total += num_samples.expect("leaf ready Join carries its shard size");
                }
                other => panic!("expected ready Join, got {other:?}"),
            }
        }
        up.send(&Message::Join { client_id: lo, num_samples: Some(total) }).unwrap();
        // the crash: the listener and every socket die together
        drop(children);
        drop(up);
        drop(listener);
        died_tx.send(()).unwrap();
    });
    (handle, died_rx)
}

#[test]
fn tcp_tree_run_survives_an_aggregator_crash_and_rejoin() {
    use feddq::sim::faults::FaultProfile;
    // The acceptance scenario for the fault-tolerant tree: a tree run
    // with simulated leaf faults composed on top loses subtree 0's
    // aggregator to a (protocol-level) kill -9 before round 0.  Its
    // leaves reconnect to the restarted aggregator on their own, the
    // restarted process re-joins upstream mid-run, the server's
    // composite handle adopts it mid-round and re-sends the round's
    // broadcast — and because the leaves replay cached answers
    // (exactly-once compute) the recovered round folds exactly what an
    // uninterrupted one would: every deterministic column, params_hash
    // included, still matches the in-process run bit for bit.  Only the
    // real-churn columns (subtree_failed, rejoined) may differ, by >= 1.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 6;
        cfg.round.topology.fanout = 2;
        cfg.sim_faults = FaultProfile::Crash { p: 0.2 };
        cfg.round.tolerance.quorum = 0.6;
        cfg.round.tolerance.round_timeout = Some(30.0);
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17933";
    let agg0 = "127.0.0.1:17934";
    let (mortal, died_rx) = mortal_aggregator(addr, agg0, 0, 2, false);
    let mut tree = Vec::new();
    for (t, lo) in (2..10u32).step_by(2).enumerate() {
        let upstream = addr.to_string();
        let agg_addr = format!("127.0.0.1:{}", 17935 + t as u16);
        tree.push(std::thread::spawn(move || {
            topology::aggregate(&upstream, &agg_addr, lo, 2, "artifacts")
                .unwrap_or_else(|e| panic!("aggregator {lo}: {e:#}"))
        }));
    }
    for id in 0..10u32 {
        let agg_addr = if id < 2 {
            agg0.to_string()
        } else {
            format!("127.0.0.1:{}", 17935 + (id / 2 - 1) as u16)
        };
        tree.push(std::thread::spawn(move || {
            topology::worker(&agg_addr, id, "artifacts")
                .unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
        }));
    }
    // The restarted aggregator: rebinds the dead one's address and
    // rejoins the run in progress.  The short delay keeps it clear of
    // the initial setup handshakes on a heavily loaded machine.
    let reborn = {
        let (addr, agg0) = (addr.to_string(), agg0.to_string());
        std::thread::spawn(move || {
            died_rx.recv().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(500));
            topology::aggregate(&addr, &agg0, 0, 2, "artifacts")
                .unwrap_or_else(|e| panic!("restarted aggregator: {e:#}"))
        })
    };
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    mortal.join().unwrap();
    // The restarted aggregator only exits on Shutdown, which the server
    // can only deliver over the re-adopted socket — joining the thread
    // is itself proof the failover path worked end to end.
    reborn.join().unwrap();
    for h in tree {
        h.join().unwrap();
    }

    assert_eq!(report.rounds.len(), 6, "the crash-hit run must complete every round");
    let subtree_failed: u32 = report.rounds.iter().map(|r| r.subtree_failed).sum();
    let rejoined: u32 = report.rounds.iter().map(|r| r.rejoined).sum();
    assert!(subtree_failed >= 1, "the killed aggregator must be recorded, got {subtree_failed}");
    assert!(rejoined >= 1, "the restarted aggregator must be recorded, got {rejoined}");

    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, b.selected, "round {}", a.round);
        assert_eq!(a.failed, b.failed, "round {}: recovery absorbs the real crash", a.round);
        assert_eq!(a.stale_folded, b.stale_folded, "round {}", a.round);
        assert_eq!(a.stale_dropped, b.stale_dropped, "round {}", a.round);
        assert_eq!(a.agg_depth, b.agg_depth, "round {}", a.round);
        assert_eq!(a.train_loss, b.train_loss, "tree vs virtual train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs virtual bits r{}", a.round);
        assert_eq!(a.client_state_bytes, b.client_state_bytes, "round {}", a.round);
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs virtual params");
}

#[test]
fn tcp_tree_orphaned_leaves_degrade_to_direct_root_attachment() {
    // Graceful degradation: subtree 8's aggregator dies before round 0
    // and never comes back.  Its leaves give up on it after the bounded
    // reconnect budget and attach directly to the root at the
    // `fallback_addr` stamped into their relayed config; the serve loop
    // retires the dead composite handle and absorbs them as direct
    // handles, and the virtual grouping folds them exactly where their
    // aggregator would have — so once degradation lands, rounds lose
    // nobody.  The round that bridges the gap fails the orphaned span
    // (leaf-granular: failed counts 2 leaves, not 1 subtree).  The dead
    // subtree is the *last* one because the server collects handles in
    // subtree order and failover on a handle burns the round budget
    // that remains — the four live partials must drain first.
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg.rounds = 4;
    cfg.round.topology.fanout = 2;
    cfg.round.tolerance.quorum = 0.6;
    // generous enough for the leaves' ~9s degrade budget to elapse
    // within the first failed round, short enough to keep the test fast
    cfg.round.tolerance.round_timeout = Some(12.0);
    let addr = "127.0.0.1:17940";
    let agg8 = "127.0.0.1:17941";
    let (mortal, _died_rx) = mortal_aggregator(addr, agg8, 8, 2, true);
    let mut tree = Vec::new();
    for (t, lo) in (0..8u32).step_by(2).enumerate() {
        let upstream = addr.to_string();
        let agg_addr = format!("127.0.0.1:{}", 17942 + t as u16);
        tree.push(std::thread::spawn(move || {
            topology::aggregate(&upstream, &agg_addr, lo, 2, "artifacts")
                .unwrap_or_else(|e| panic!("aggregator {lo}: {e:#}"))
        }));
    }
    for id in 0..10u32 {
        let agg_addr = if id >= 8 {
            agg8.to_string()
        } else {
            format!("127.0.0.1:{}", 17942 + (id / 2) as u16)
        };
        tree.push(std::thread::spawn(move || {
            topology::worker(&agg_addr, id, "artifacts")
                .unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
        }));
    }
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    mortal.join().unwrap();
    for h in tree {
        h.join().unwrap();
    }

    assert_eq!(report.rounds.len(), 4, "the orphaned run must complete every round");
    let subtree_failed: u32 = report.rounds.iter().map(|r| r.subtree_failed).sum();
    assert!(subtree_failed >= 1, "the dead aggregator must be recorded, got {subtree_failed}");
    let degraded: u32 = report.rounds.iter().map(|r| r.degraded).sum();
    assert!(degraded >= 2, "both orphaned leaves must degrade, got {degraded}");
    let rejoined: u32 = report.rounds.iter().map(|r| r.rejoined).sum();
    assert_eq!(rejoined, 0, "a degraded leaf attach is not an aggregator rejoin");
    let first = &report.rounds[0];
    assert_eq!(first.failed, 2, "the bridging round fails the orphaned span's two leaves");
    let last = report.rounds.last().unwrap();
    assert_eq!(last.failed, 0, "degradation restores the full cohort");
    assert_eq!(last.degraded, 2, "both direct handles serve the final round");
    assert_eq!(last.agg_depth, 2, "virtual grouping keeps the tree depth for direct leaves");
}

#[test]
fn banked_ef_session_matches_fp32_banking_at_32_bits_of_headroom() {
    // --ef-bits re-quantizes the EF residual between rounds.  At 8
    // bits the trajectory must differ from fp32 banking (the banking
    // loss is real) yet stay finite; with the knob off (ef_bits = 0)
    // the run is bit-for-bit the historical EF run.
    let mut cfg = tiny_cfg(PolicyConfig::Fixed { bits: 2 });
    cfg.error_feedback = true;
    cfg.ef_bits = 8;
    cfg.rounds = 5;
    let banked = Session::new(cfg).unwrap().run().unwrap();
    assert_eq!(banked.rounds.len(), 5);
    for r in &banked.rounds {
        assert!(r.train_loss.is_finite());
    }
    let mut cfg2 = tiny_cfg(PolicyConfig::Fixed { bits: 2 });
    cfg2.error_feedback = true;
    cfg2.rounds = 5;
    let fp32 = Session::new(cfg2).unwrap().run().unwrap();
    assert_ne!(
        banked.rounds.last().unwrap().train_loss,
        fp32.rounds.last().unwrap().train_loss,
        "8-bit banking must leave a (bounded) mark on the trajectory"
    );
    // ef_bits = 0 is the identity: same struct, same run
    let mut cfg3 = tiny_cfg(PolicyConfig::Fixed { bits: 2 });
    cfg3.error_feedback = true;
    cfg3.ef_bits = 0;
    cfg3.rounds = 5;
    let off = Session::new(cfg3).unwrap().run().unwrap();
    assert_eq!(off.params_hash, fp32.params_hash, "ef_bits 0 must change nothing");
}

#[test]
fn error_feedback_session_runs_and_stays_finite() {
    let mut cfg = tiny_cfg(PolicyConfig::Fixed { bits: 2 });
    cfg.error_feedback = true;
    cfg.rounds = 5;
    let report = Session::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), 5);
    for r in &report.rounds {
        assert!(r.train_loss.is_finite());
    }
    // EF must change the trajectory vs plain 2-bit (residuals feed back)
    let mut cfg2 = tiny_cfg(PolicyConfig::Fixed { bits: 2 });
    cfg2.rounds = 5;
    let plain = Session::new(cfg2).unwrap().run().unwrap();
    assert_ne!(
        report.rounds.last().unwrap().train_loss,
        plain.rounds.last().unwrap().train_loss
    );
}

#[test]
fn feddq_whole_granularity_is_uniform_and_coarser() {
    let mut cfg = tiny_cfg(PolicyConfig::FedDqWhole { resolution: 0.005 });
    cfg.rounds = 2;
    let whole = Session::new(cfg).unwrap().run().unwrap();
    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    cfg2.rounds = 2;
    let per_seg = Session::new(cfg2).unwrap().run().unwrap();
    // whole-model bit budget >= per-segment budget (max range rules all)
    assert!(whole.rounds[0].mean_bits >= per_seg.rounds[0].mean_bits);
}

#[test]
fn network_model_orders_policies_by_bits() {
    use feddq::sim::NetworkModel;
    let fed = Session::new(tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 }))
        .unwrap()
        .run()
        .unwrap();
    let fp = Session::new(tiny_cfg(PolicyConfig::Fp32)).unwrap().run().unwrap();
    let nm = NetworkModel::wan(10);
    let t_fed = nm.replay(&fed, 101770, 1).last().unwrap().cum_secs;
    let t_fp = nm.replay(&fp, 101770, 1).last().unwrap().cum_secs;
    assert!(
        t_fed < t_fp,
        "quantized run must be faster on a constrained uplink: {t_fed} vs {t_fp}"
    );
}

#[test]
fn semisync_tcp_run_banks_and_folds_stragglers_like_local() {
    use feddq::sim::faults::FaultProfile;
    // Bounded staleness over real sockets: the scheduler's seed-pure
    // churn marks stalled workers two rounds late (t = 75s against a
    // T = 30s budget gives s = 2), their on-wire updates are banked at
    // dispatch and folded with discounted weight two rounds later — and
    // the whole run must agree with the in-process session bit for bit,
    // bank and all, because folds are keyed by (round, client id) and
    // never by arrival order.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 4;
        cfg.sim_faults = FaultProfile::Stall { p: 0.5, secs: 75.0 };
        cfg.round.tolerance.round_timeout = Some(30.0);
        // 0.05, not 0.1: late members inflate n without delivering
        // on-time, and f32 0.1 widens past 0.1 (ceil(q·10) = 2) — the
        // floor must stay at 1 for a 9-late round to pass quorum.
        cfg.round.tolerance.quorum = 0.05;
        cfg.round.tolerance.staleness = 2;
    };
    let mut cfg = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17877";
    let n = 10;
    let workers: Vec<_> = (0..n)
        .map(|id| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                topology::worker(&addr, id, "artifacts").unwrap_or_else(|e| panic!("worker {id}: {e:#}"))
            })
        })
        .collect();
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for w in workers {
        w.join().unwrap();
    }
    let folded: u32 = report.rounds.iter().map(|r| r.stale_folded).sum();
    assert!(folded >= 1, "stall:0.5:75 under --staleness 2 must fold a straggler");

    let mut cfg2 = tiny_cfg(PolicyConfig::FedDq { resolution: 0.005 });
    knobs(&mut cfg2);
    let local = Session::new(cfg2).unwrap().run().unwrap();
    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, b.selected, "round {}", a.round);
        assert_eq!(a.failed, b.failed, "round {}", a.round);
        assert_eq!(a.stale_folded, b.stale_folded, "round {}", a.round);
        assert_eq!(a.stale_dropped, b.stale_dropped, "round {}", a.round);
        assert_eq!(a.train_loss, b.train_loss, "tcp vs local train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tcp vs local bits r{}", a.round);
    }
    assert_eq!(report.params_hash, local.params_hash, "tcp vs local params");
}

#[test]
fn budget_below_one_bit_per_element_is_rejected() {
    // RunConfig::validate can't see the model dimension, so the 1-bit
    // floor is the server's to enforce: a cap that can't give a single
    // client 1 bit/element fails at round-engine construction, not
    // with a silent starve.
    let mut cfg = tiny_cfg(PolicyConfig::Fixed { bits: 8 });
    cfg.error_feedback = true;
    cfg.round.budget.bit_budget = 1000; // d = 101770
    let err = Session::new(cfg).unwrap().run().unwrap_err();
    assert!(
        format!("{err:#}").contains("floor"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn tcp_tree_with_budget_and_quantized_downlink_matches_local() {
    // The closed loop over real sockets: per-client budgets ride the
    // broadcast frame down the tree, workers hold a replica and apply
    // the quantized delta chain, and the analytic downlink ledger is
    // charged per dispatched leaf — so a two-tier tree must stay
    // bit-identical to the in-process session, budgets, replicas,
    // downlink columns, params hash and all.
    let knobs = |cfg: &mut RunConfig| {
        cfg.rounds = 4;
        cfg.policy = PolicyConfig::Fixed { bits: 8 };
        cfg.error_feedback = true;
        // ~2 bits/element across the 10-client cohort: the clamp binds
        cfg.round.budget.bit_budget = 10 * 101_770 * 2;
        cfg.round.budget.downlink_bits = 4;
        cfg.round.topology.fanout = 2;
    };
    let mut cfg = tiny_cfg(PolicyConfig::Fixed { bits: 8 });
    knobs(&mut cfg);
    let addr = "127.0.0.1:17951";
    let tree = spawn_tree(addr, 17953, 10, 2);
    let report = topology::serve(&cfg, addr, |_, _| {}).unwrap();
    for h in tree {
        h.join().unwrap();
    }

    let mut cfg2 = tiny_cfg(PolicyConfig::Fixed { bits: 8 });
    knobs(&mut cfg2);
    let mut session = Session::new(cfg2).unwrap();
    let d = session.manifest().d as u64;
    let local = session.run().unwrap();

    assert_eq!(report.rounds.len(), local.rounds.len());
    for (a, b) in report.rounds.iter().zip(&local.rounds) {
        assert_eq!(a.selected, b.selected, "round {}", a.round);
        assert_eq!(a.train_loss, b.train_loss, "tree vs local train loss r{}", a.round);
        assert_eq!(a.uplink_bits, b.uplink_bits, "tree vs local uplink r{}", a.round);
        assert_eq!(
            a.downlink_bits, b.downlink_bits,
            "tree vs local downlink r{}",
            a.round
        );
        assert_eq!(
            a.cum_downlink_bits, b.cum_downlink_bits,
            "tree vs local cum downlink r{}",
            a.round
        );
    }
    assert_eq!(report.params_hash, local.params_hash, "tree vs local params");

    // Round 0 is the full fp32 init; every later round rides the 4-bit
    // delta chain, so the whole run must undercut what an fp32
    // broadcast ledger would have charged.
    assert_eq!(report.rounds[0].downlink_bits, 10 * d * 32);
    let fp32_cost: u64 = report.rounds.iter().map(|r| r.selected as u64 * d * 32).sum();
    let last = report.rounds.last().unwrap();
    assert!(
        last.cum_downlink_bits < fp32_cost,
        "quantized downlink {} must undercut the fp32 broadcast cost {fp32_cost}",
        last.cum_downlink_bits
    );
}
