"""Kernel-vs-reference correctness: the core L1 signal.

hypothesis sweeps segment partitions, magnitudes, levels and seeds; every
kernel must agree with the pure-jnp oracle in ref.py elementwise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, layout as L, quantize, ref, segrange

jax.config.update("jax_platform_name", "cpu")

seg_sizes_st = st.lists(st.integers(min_value=1, max_value=3000), min_size=1, max_size=8)


def make_update(lay, seed, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return scale * jax.random.normal(key, (lay.d,), jnp.float32)


class TestLayout:
    @given(seg_sizes_st)
    @settings(max_examples=40, deadline=None)
    def test_layout_invariants(self, sizes):
        lay = L.make_layout(sizes)
        assert lay.d == sum(sizes)
        assert lay.padded == lay.tiles * L.TILE
        assert lay.padded >= lay.d
        # every tile belongs to exactly one segment, contiguous
        assert list(lay.tile_seg_ids) == sorted(lay.tile_seg_ids)
        assert sum(lay.tile_valid) == lay.d
        assert all(1 <= v <= L.TILE for v in lay.tile_valid)

    @given(seg_sizes_st, st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pad_unpad_roundtrip(self, sizes, seed):
        lay = L.make_layout(sizes)
        x = make_update(lay, seed)
        xp = L.pad(lay, x)
        assert xp.shape == (lay.padded,)
        np.testing.assert_array_equal(np.asarray(L.unpad(lay, xp)), np.asarray(x))

    def test_rejects_bad_segments(self):
        with pytest.raises(ValueError):
            L.make_layout([])
        with pytest.raises(ValueError):
            L.make_layout([4, 0, 2])

    def test_expand_per_tile(self):
        lay = L.make_layout([5, 2048, 3])
        per_seg = jnp.array([10.0, 20.0, 30.0])
        out = np.asarray(L.expand_per_tile(lay, per_seg))
        np.testing.assert_array_equal(out, [10.0, 20.0, 20.0, 30.0])


class TestSegmentRanges:
    @given(seg_sizes_st, st.integers(0, 2**31 - 1),
           st.sampled_from([1e-4, 1.0, 1e4]))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, sizes, seed, scale):
        lay = L.make_layout(sizes)
        x = make_update(lay, seed, scale)
        mins, ranges = segrange.segment_ranges(lay, x)
        rmins, rranges = ref.segment_ranges_ref(lay, x)
        np.testing.assert_allclose(np.asarray(mins), np.asarray(rmins), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ranges), np.asarray(rranges), rtol=1e-6)

    def test_constant_segment_has_zero_range(self):
        lay = L.make_layout([100, 50])
        x = jnp.concatenate([jnp.full((100,), 3.5), jnp.zeros((50,))])
        mins, ranges = segrange.segment_ranges(lay, x)
        np.testing.assert_allclose(np.asarray(mins), [3.5, 0.0])
        np.testing.assert_allclose(np.asarray(ranges), [0.0, 0.0])

    def test_padding_cannot_leak(self):
        # all-positive segment of 1 element: zero padding would corrupt min
        lay = L.make_layout([1, 1])
        x = jnp.array([7.0, -7.0])
        mins, ranges = segrange.segment_ranges(lay, x)
        np.testing.assert_allclose(np.asarray(mins), [7.0, -7.0])
        np.testing.assert_allclose(np.asarray(ranges), [0.0, 0.0])


class TestStochasticQuantize:
    @given(seg_sizes_st, st.integers(0, 2**31 - 1),
           st.lists(st.sampled_from([1, 3, 15, 255, 65535]), min_size=8, max_size=8))
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, sizes, seed, levels8):
        lay = L.make_layout(sizes)
        nseg = lay.num_segments
        x = make_update(lay, seed)
        mins, ranges = ref.segment_ranges_ref(lay, x)
        s = jnp.asarray(levels8[:nseg], jnp.float32)
        sinv = jnp.where(ranges > 1e-12, s / jnp.maximum(ranges, 1e-12), 0.0)
        u = jax.random.uniform(jax.random.PRNGKey(seed ^ 0xF00D), (lay.padded,))
        got = np.asarray(quantize.stochastic_quantize(lay, x, mins, sinv, s, u))
        want = np.asarray(ref.stochastic_quantize_ref(lay, x, mins, sinv, s, u))
        # The kernel and the oracle may round differently when
        # (x - min) * sinv + u lands exactly on a bin boundary (XLA fuses
        # the expression into an FMA in one lowering but not the other).
        # A ±1 code at boundary-hit frequency is within the stochastic
        # quantizer's contract; anything more is a real bug.
        diff = np.abs(got - want)
        assert diff.max() <= 1, f"code error > 1 bin: {diff.max()}"
        assert (diff != 0).mean() <= 0.01, f"boundary-rate too high: {(diff != 0).mean()}"

    def test_codes_in_range_and_integral(self):
        lay = L.make_layout([5000])
        x = make_update(lay, 3)
        mins, ranges = ref.segment_ranges_ref(lay, x)
        s = jnp.array([15.0])
        sinv = s / ranges
        u = jax.random.uniform(jax.random.PRNGKey(1), (lay.padded,))
        codes = np.asarray(quantize.stochastic_quantize(lay, x, mins, sinv, s, u))
        assert codes.min() >= 0 and codes.max() <= 15
        np.testing.assert_array_equal(codes, np.round(codes))

    def test_unbiasedness(self):
        # E[dequant(Q(x))] == x: the quantizer's defining property (Assumption 1).
        lay = L.make_layout([64])
        x = make_update(lay, 9)
        mins, ranges = ref.segment_ranges_ref(lay, x)
        s = jnp.array([7.0])
        sinv = s / ranges
        step = ranges / s
        acc = np.zeros(lay.d)
        trials = 600
        for t in range(trials):
            u = jax.random.uniform(jax.random.PRNGKey(1000 + t), (lay.padded,))
            codes = quantize.stochastic_quantize(lay, x, mins, sinv, s, u)
            acc += np.asarray(codes) * float(step[0]) + float(mins[0])
        est = acc / trials
        # stderr of the estimate is ~ step/sqrt(12 trials) ≈ 0.012*|range|
        np.testing.assert_allclose(est, np.asarray(x), atol=4.5 * float(step[0]) / np.sqrt(trials) + 1e-7)

    def test_variance_bound(self):
        # Var[Q(x) - x] <= (range/s)^2 / 4 per element (uniform stochastic
        # rounding within one bin) — implies the paper's Assumption 1 bound.
        lay = L.make_layout([256])
        x = make_update(lay, 5)
        mins, ranges = ref.segment_ranges_ref(lay, x)
        s = jnp.array([15.0])
        sinv = s / ranges
        step = float(ranges[0] / s[0])
        errs = []
        for t in range(200):
            u = jax.random.uniform(jax.random.PRNGKey(t), (lay.padded,))
            codes = quantize.stochastic_quantize(lay, x, mins, sinv, s, u)
            deq = np.asarray(codes) * step + float(mins[0])
            errs.append(deq - np.asarray(x))
        var = np.var(np.stack(errs), axis=0)
        assert var.max() <= step * step / 4 * 1.25  # slack for sampling noise

    def test_degenerate_range_collapses_to_zero_codes(self):
        lay = L.make_layout([128])
        x = jnp.full((128,), 2.5)
        s = jnp.array([255.0])
        u = jax.random.uniform(jax.random.PRNGKey(0), (lay.padded,))
        codes = quantize.stochastic_quantize(lay, x, jnp.array([2.5]), jnp.array([0.0]), s, u)
        np.testing.assert_array_equal(np.asarray(codes), np.zeros(128))


class TestDequantAggregate:
    @given(seg_sizes_st, st.integers(2, 6), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_matches_ref(self, sizes, n, seed):
        lay = L.make_layout(sizes)
        key = jax.random.PRNGKey(seed)
        codes = jnp.floor(
            jax.random.uniform(key, (n, lay.d), minval=0.0, maxval=16.0)
        )
        k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed ^ 1), 3)
        mins = jax.random.normal(k2, (n, lay.num_segments))
        steps = jax.random.uniform(k3, (n, lay.num_segments), minval=0.0, maxval=0.1)
        w = jax.random.uniform(k4, (n,), minval=0.1, maxval=1.0)
        w = w / jnp.sum(w)
        got = aggregate.dequant_aggregate(lay, codes, mins, steps, w)
        want = ref.dequant_aggregate_ref(lay, codes, mins, steps, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_fp32_passthrough_convention(self):
        # codes=delta, step=1, min=0 must reproduce the weighted mean exactly.
        lay = L.make_layout([300, 40])
        n = 3
        deltas = jnp.stack([make_update(lay, i) for i in range(n)])
        w = jnp.array([0.5, 0.25, 0.25])
        mins = jnp.zeros((n, 2))
        steps = jnp.ones((n, 2))
        got = aggregate.dequant_aggregate(lay, deltas, mins, steps, w)
        want = jnp.einsum("i,id->d", w, deltas)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-7)
