"""L2 model zoo tests: shapes, gradients, training dynamics, export specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

MODELS = ["mlp", "vanilla_cnn", "cnn4", "resnet18"]


@pytest.fixture(scope="module")
def flats():
    return {name: M.flat_model(name, C.CONFIGS[name]["model"]) for name in MODELS}


class TestFlatModel:
    @pytest.mark.parametrize("name", MODELS)
    def test_flatten_unflatten_roundtrip(self, flats, name):
        fm = flats[name]
        p, = M.make_init(fm)(jnp.uint32(0))
        assert p.shape == (fm.d,)
        tree = fm.unflatten(p)
        assert set(tree) == {s.name for s in fm.model.specs}
        back = fm.flatten(tree)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(p))

    @pytest.mark.parametrize("name", MODELS)
    def test_init_deterministic_and_seed_sensitive(self, flats, name):
        fm = flats[name]
        a, = M.make_init(fm)(jnp.uint32(7))
        b, = M.make_init(fm)(jnp.uint32(7))
        c, = M.make_init(fm)(jnp.uint32(8))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    @pytest.mark.parametrize("name", MODELS)
    def test_segment_layout_matches_specs(self, flats, name):
        fm = flats[name]
        off = 0
        for sid, spec in enumerate(fm.model.specs):
            assert fm.lay.seg_offsets[sid] == off
            assert fm.lay.seg_sizes[sid] == spec.size
            off += spec.size
        assert off == fm.d


class TestRoundFunction:
    @pytest.mark.parametrize("name", ["mlp", "vanilla_cnn"])
    def test_loss_decreases_on_memorizable_batch(self, flats, name):
        fm = flats[name]
        cfg = C.CONFIGS[name]
        rnd = jax.jit(M.make_round(fm))
        p, = M.make_init(fm)(jnp.uint32(0))
        tau, b = cfg["tau"], cfg["batch"]
        ish = fm.model.input_shape
        xs = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (1, b, *ish))
        xs = jnp.tile(xs, (tau, 1) + (1,) * len(ish))
        ys = jnp.tile(jax.random.randint(jax.random.PRNGKey(2), (1, b), 0, 10), (tau, 1))
        losses = []
        for _ in range(8):
            delta, loss = rnd(p, xs, ys, jnp.float32(0.05))
            p = p + delta
            losses.append(float(loss))
        assert min(losses) < losses[0] * 0.7, losses

    @pytest.mark.parametrize("name", MODELS)
    def test_delta_is_finite_and_nonzero(self, flats, name):
        fm = flats[name]
        cfg = C.CONFIGS[name]
        rnd = jax.jit(M.make_round(fm))
        p, = M.make_init(fm)(jnp.uint32(3))
        tau, b = cfg["tau"], cfg["batch"]
        ish = fm.model.input_shape
        xs = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (tau, b, *ish))
        ys = jax.random.randint(jax.random.PRNGKey(5), (tau, b), 0, 10)
        delta, loss = rnd(p, xs, ys, jnp.float32(0.05))
        assert np.isfinite(float(loss))
        d = np.asarray(delta)
        assert np.isfinite(d).all()
        assert np.abs(d).max() > 0

    def test_zero_lr_means_zero_delta(self, flats):
        fm = flats["mlp"]
        cfg = C.CONFIGS["mlp"]
        rnd = jax.jit(M.make_round(fm))
        p, = M.make_init(fm)(jnp.uint32(0))
        tau, b = cfg["tau"], cfg["batch"]
        xs = jax.random.normal(jax.random.PRNGKey(1), (tau, b, 28, 28, 1))
        ys = jax.random.randint(jax.random.PRNGKey(2), (tau, b), 0, 10)
        delta, _ = rnd(p, xs, ys, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(delta), np.zeros(fm.d))


class TestEvaluate:
    def test_counts_and_loss(self, flats):
        fm = flats["mlp"]
        ev = jax.jit(M.make_evaluate(fm))
        p, = M.make_init(fm)(jnp.uint32(0))
        e = C.CONFIGS["mlp"]["eval_batch"]
        xs = jax.random.normal(jax.random.PRNGKey(1), (e, 28, 28, 1))
        ys = jax.random.randint(jax.random.PRNGKey(2), (e,), 0, 10)
        loss_sum, correct = ev(p, xs, ys)
        assert 0 <= int(correct) <= e
        assert float(loss_sum) / e == pytest.approx(np.log(10), rel=0.5)


class TestExportSpecs:
    @pytest.mark.parametrize("name", MODELS)
    def test_all_executables_present_with_shapes(self, flats, name):
        fm = flats[name]
        cfg = C.CONFIGS[name]
        specs = M.export_specs(fm, cfg["tau"], cfg["batch"], cfg["eval_batch"], cfg["n_clients"])
        assert set(specs) == {"init", "round", "evaluate", "ranges", "quantize", "aggregate"}
        _, qargs = specs["quantize"]
        assert qargs[0].shape == (fm.d,)
        assert qargs[1].shape == (fm.num_segments,)
        _, aargs = specs["aggregate"]
        assert aargs[0].shape == (cfg["n_clients"], fm.d)

    def test_resnet_has_resnet18_topology(self, flats):
        fm = flats["resnet18"]
        names = [s.name for s in fm.model.specs]
        import re
        blocks = {n.split(".")[0] for n in names if re.match(r"^s\d+b\d+\.", n)}
        assert blocks == {f"s{i}b{j}" for i in range(4) for j in range(2)}
        assert any(n == "stem.w" for n in names)
        assert any(n.endswith("proj.w") for n in names)  # strided shortcuts


class TestAotManifest:
    def test_manifest_matches_current_configs(self, tmp_path):
        # aot --models mlp into a temp dir and validate the manifest entry.
        import json
        import subprocess
        import sys

        out = tmp_path / "arts"
        res = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out), "--models", "mlp"],
            capture_output=True,
            text=True,
        )
        assert res.returncode == 0, res.stderr
        manifest = json.loads((out / "manifest.json").read_text())
        entry = manifest["models"]["mlp"]
        fm = M.flat_model("mlp", C.CONFIGS["mlp"]["model"])
        assert entry["d"] == fm.d
        assert entry["num_segments"] == fm.num_segments
        assert [s["size"] for s in entry["segments"]] == list(fm.lay.seg_sizes)
        for ex in ["init", "round", "evaluate", "ranges", "quantize", "aggregate"]:
            assert (out / entry["executables"][ex]["file"]).exists()

    def test_hlo_has_no_elided_constants(self):
        # Regression test for the constant-elision bug: `constant({...})`
        # in the HLO text silently zeroes lookup tables on the Rust side.
        import glob
        import os

        arts = os.environ.get("FEDDQ_ARTIFACTS", "../artifacts")
        files = glob.glob(os.path.join(arts, "*.hlo.txt"))
        if not files:
            pytest.skip("artifacts not built")
        for f in files:
            assert "constant({...})" not in open(f).read(), f
