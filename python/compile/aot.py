"""AOT entry point: lower every model's executables to HLO text + manifest.

HLO *text* is the interchange format, never ``.serialize()``: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--models mlp,cnn4] [--force]

Writes ``<model>_<fn>.hlo.txt`` per executable plus ``manifest.json``
describing shapes, segment layout and static hyper-parameters — the single
source of truth the Rust runtime loads at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import configs as C
from . import model as M

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constant arrays as ``constant({...})`` and the text parser
    on the Rust side silently reads them back as zeros — which corrupts
    any computation with a baked-in lookup table (tile->segment maps,
    valid-lane counts, ...).  Found the hard way; see DESIGN.md §2.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def spec_json(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def build_model_artifacts(name: str, cfg: dict, out_dir: str,
                          force: bool) -> dict:
    fm = M.flat_model(name, cfg["model"])
    tau, batch = cfg["tau"], cfg["batch"]
    eval_batch, n_clients = cfg["eval_batch"], cfg["n_clients"]
    exports = M.export_specs(fm, tau, batch, eval_batch, n_clients)

    entry: dict = {
        "d": fm.d,
        "padded": fm.lay.padded,
        "tile": fm.lay.tiles and (fm.lay.padded // fm.lay.tiles),
        "tiles": fm.lay.tiles,
        "num_segments": fm.num_segments,
        "segments": [
            {
                "name": s.name,
                "offset": fm.lay.seg_offsets[i],
                "size": s.size,
                "shape": list(s.shape),
            }
            for i, s in enumerate(fm.model.specs)
        ],
        "input_shape": list(fm.model.input_shape),
        "classes": fm.model.num_classes,
        "tau": tau,
        "batch": batch,
        "eval_batch": eval_batch,
        "n_clients": n_clients,
        "executables": {},
    }

    for fn_name, (fn, specs) in exports.items():
        fname = f"{name}_{fn_name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        t0 = time.time()
        if force or not os.path.exists(path):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            status = f"lowered in {time.time() - t0:.1f}s ({len(text)} chars)"
        else:
            status = "cached"
        print(f"  {fname}: {status}", flush=True)
        entry["executables"][fn_name] = {
            "file": fname,
            "args": [spec_json(s) for s in specs],
        }
    return entry


def config_fingerprint(cfg: dict) -> str:
    """Per-model config fingerprint — cache key for that model's artifacts."""
    blob = json.dumps(cfg, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list or 'all'")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the HLO file exists")
    ap.add_argument("--scale", default=None, choices=[None, "cpu", "paper"],
                    help="width scale (default: FEDDQ_SCALE env or 'cpu')")
    args = ap.parse_args()

    cfgs = C.build_configs(args.scale)
    names = sorted(cfgs) if args.models == "all" else args.models.split(",")
    for n in names:
        if n not in cfgs:
            print(f"unknown model {n!r}; have {sorted(cfgs)}", file=sys.stderr)
            return 2

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"version": MANIFEST_VERSION, "models": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("version") == MANIFEST_VERSION:
            # keep every previously-built model; stale ones are re-lowered
            # below when their per-model fingerprint no longer matches
            manifest["models"] = old.get("models", {})

    for n in names:
        print(f"[aot] {n}", flush=True)
        fp = config_fingerprint(cfgs[n])
        stale = manifest["models"].get(n, {}).get("fingerprint") != fp
        entry = build_model_artifacts(n, cfgs[n], args.out, args.force or stale)
        entry["fingerprint"] = fp
        manifest["models"][n] = entry

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
