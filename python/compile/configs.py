"""Artifact-build configurations — one per paper benchmark plus `mlp`.

These bake the *static* choices (shapes, tau, batch sizes, client count)
into the AOT-lowered executables; everything dynamic (learning rate,
quantization levels, seeds, policy) stays a runtime input owned by the
Rust coordinator.

Paper setup (§V-A): tau=5, eta=0.1, SGD; clients = 10 / 10 / 4 for the
three benchmarks.  eta stays a runtime input; tau and client counts are
baked here to match.

`scale` selects between "cpu" (default; widths scaled down so hundreds of
federated rounds run on the CPU PJRT backend — see DESIGN.md §3) and
"paper" (the canonical widths).  Select with FEDDQ_SCALE=paper.
"""

from __future__ import annotations

import os


def build_configs(scale: str | None = None) -> dict[str, dict]:
    scale = scale or os.environ.get("FEDDQ_SCALE", "cpu")
    if scale not in ("cpu", "paper"):
        raise ValueError(f"unknown scale {scale!r}")
    paper = scale == "paper"
    return {
        "mlp": {
            "model": {
                "input_shape": (28, 28, 1),
                "classes": 10,
                "hidden": 128,
            },
            "tau": 5,
            "batch": 32,
            "eval_batch": 500,
            "n_clients": 10,
        },
        "vanilla_cnn": {
            # benchmark 1: Fashion-MNIST
            "model": {
                "input_shape": (28, 28, 1),
                "classes": 10,
                "conv1": 32 if paper else 8,
                "conv2": 64 if paper else 16,
                "fc": 512 if paper else 64,
            },
            "tau": 5,
            "batch": 32,
            "eval_batch": 500,
            "n_clients": 10,
        },
        "cnn4": {
            # benchmark 2: CIFAR-10
            "model": {
                "input_shape": (32, 32, 3),
                "classes": 10,
                # 1-core CPU testbed: widths halved again vs the first
                # cpu scale so a 25-round comparison stays tractable
                # (layer count — the paper's structure — is unchanged).
                "conv1": 64 if paper else 8,
                "conv2": 64 if paper else 8,
                "conv3": 128 if paper else 16,
                "conv4": 128 if paper else 16,
                "fc1": 256 if paper else 64,
                "fc2": 128 if paper else 32,
            },
            "tau": 5,
            "batch": 32,
            "eval_batch": 500,
            "n_clients": 10,
        },
        "resnet18": {
            # benchmark 3: CIFAR-10
            "model": {
                "input_shape": (32, 32, 3),
                "classes": 10,
                "base": 64 if paper else 8,
            },
            "tau": 5,
            "batch": 32,
            "eval_batch": 500,
            "n_clients": 4,
        },
    }


CONFIGS = build_configs()
