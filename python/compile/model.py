"""L2 assembly: flat-parameter machinery and the five exported functions.

Every model is exported to the Rust runtime as a family of stateless XLA
executables over a single flat ``f32[d]`` parameter vector:

  init(seed)                                   -> params[d]
  round(params, xs[tau,B,...], ys[tau,B], lr)  -> (delta[d], mean_loss)
  evaluate(params, xs[E,...], ys[E])           -> (loss_sum, correct)
  ranges(delta)                                -> (mins[L], ranges[L])
  quantize(delta, mins[L], sinv[L], maxc[L], seed) -> codes[d]
  aggregate(codes[n,d], mins[n,L], steps[n,L], w[n]) -> delta[d]

``round`` runs the paper's tau local SGD steps (Eq. 2-3) inside one
``lax.scan`` so a whole client round is a single PJRT dispatch.  ``ranges``
+ ``quantize`` split the client wire path so the L3 policy can choose the
bit-width *between* them from the observed update range (Eq. 10) — the
policy decision lives in Rust, the number crunching in XLA/Pallas.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import aggregate as k_agg
from .kernels import layout as k_layout
from .kernels import quantize as k_quant
from .kernels import segrange as k_range
from .models import ModelDef, build_model
from .models import common as mc


@dataclasses.dataclass(frozen=True)
class FlatModel:
    """A ModelDef plus its flat-vector layout and segment metadata."""

    model: ModelDef
    lay: k_layout.PaddedLayout

    @property
    def d(self) -> int:
        return self.lay.d

    @property
    def num_segments(self) -> int:
        return self.lay.num_segments

    def unflatten(self, flat: jnp.ndarray) -> dict:
        tree = {}
        for sid, spec in enumerate(self.model.specs):
            o = self.lay.seg_offsets[sid]
            tree[spec.name] = flat[o : o + spec.size].reshape(spec.shape)
        return tree

    def flatten(self, tree: dict) -> jnp.ndarray:
        return jnp.concatenate(
            [tree[s.name].reshape(-1) for s in self.model.specs]
        )


def flat_model(name: str, cfg: dict) -> FlatModel:
    model = build_model(name, cfg)
    lay = k_layout.make_layout([s.size for s in model.specs])
    return FlatModel(model, lay)


# ---------------------------------------------------------------------------
# exported functions
# ---------------------------------------------------------------------------


def make_init(fm: FlatModel) -> Callable:
    def init(seed: jnp.ndarray) -> jnp.ndarray:
        tree = mc.init_params(seed, fm.model.specs)
        return (fm.flatten(tree),)

    return init


def make_loss(fm: FlatModel) -> Callable:
    def loss_fn(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
        logits = fm.model.apply(fm.unflatten(flat), x)
        return mc.cross_entropy(logits, y)

    return loss_fn


def make_round(fm: FlatModel) -> Callable:
    """tau local SGD steps -> (model update delta, mean train loss)."""
    loss_fn = make_loss(fm)
    grad_fn = jax.value_and_grad(loss_fn)

    def local_round(params, xs, ys, lr):
        # xs: [tau, B, ...], ys: [tau, B] int32, lr: scalar
        def step(p, batch):
            x, y = batch
            loss, g = grad_fn(p, x, y)
            return p - lr * g, loss

        p_final, losses = jax.lax.scan(step, params, (xs, ys))
        return p_final - params, jnp.mean(losses)

    return local_round


def make_evaluate(fm: FlatModel) -> Callable:
    def evaluate(params, xs, ys):
        logits = fm.model.apply(fm.unflatten(params), xs)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, ys[:, None], axis=1)[:, 0]
        return jnp.sum(nll), mc.correct_count(logits, ys)

    return evaluate


def make_ranges(fm: FlatModel) -> Callable:
    def ranges(delta):
        return k_range.segment_ranges(fm.lay, delta)

    return ranges


def make_quantize(fm: FlatModel) -> Callable:
    def quantize(delta, mins, sinv, maxcode, seed):
        key = jax.random.PRNGKey(seed)
        u = jax.random.uniform(key, (fm.lay.padded,), jnp.float32)
        return (k_quant.stochastic_quantize(fm.lay, delta, mins, sinv, maxcode, u),)

    return quantize


def make_aggregate(fm: FlatModel) -> Callable:
    def aggregate(codes, mins, steps, weights):
        return (k_agg.dequant_aggregate(fm.lay, codes, mins, steps, weights),)

    return aggregate


# ---------------------------------------------------------------------------
# argument specs for AOT lowering (shapes must match the manifest)
# ---------------------------------------------------------------------------


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def export_specs(fm: FlatModel, tau: int, batch: int, eval_batch: int,
                 n_clients: int) -> dict[str, tuple[Callable, tuple]]:
    """(fn, arg_specs) for every executable of this model."""
    d = fm.d
    L = fm.num_segments
    ish = fm.model.input_shape
    return {
        "init": (make_init(fm), (u32(),)),
        "round": (
            make_round(fm),
            (f32(d), f32(tau, batch, *ish), i32(tau, batch), f32()),
        ),
        "evaluate": (
            make_evaluate(fm),
            (f32(d), f32(eval_batch, *ish), i32(eval_batch)),
        ),
        "ranges": (make_ranges(fm), (f32(d),)),
        "quantize": (
            make_quantize(fm),
            (f32(d), f32(L), f32(L), f32(L), u32()),
        ),
        "aggregate": (
            make_aggregate(fm),
            (f32(n_clients, d), f32(n_clients, L), f32(n_clients, L),
             f32(n_clients)),
        ),
    }
