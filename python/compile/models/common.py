"""Shared functional building blocks for the L2 model zoo.

Models are pure functions over a ``dict[str, Array]`` parameter tree plus a
static, ordered parameter *spec* — the ordering defines the flat-vector
layout (and hence the quantization segments) used across the whole stack,
so it must be deterministic and identical between python and the manifest
consumed by Rust.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter tensor: name, shape and initializer family."""

    name: str
    shape: tuple[int, ...]
    init: str  # "he" | "glorot" | "zeros" | "ones"
    fan_in: int = 0

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model: ordered parameter spec + apply function + IO metadata."""

    name: str
    specs: tuple[ParamSpec, ...]
    apply: Callable  # (params: dict, x: [B, ...]) -> logits [B, classes]
    input_shape: tuple[int, ...]
    num_classes: int

    @property
    def num_params(self) -> int:
        return sum(s.size for s in self.specs)


def init_param(key: jax.Array, spec: ParamSpec) -> jnp.ndarray:
    """Initialize one tensor according to its spec."""
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, jnp.float32)
    if spec.init == "ones":
        return jnp.ones(spec.shape, jnp.float32)
    if spec.init.startswith("const:"):
        return jnp.full(spec.shape, float(spec.init.split(":")[1]), jnp.float32)
    if spec.init == "he":
        std = math.sqrt(2.0 / max(spec.fan_in, 1))
    elif spec.init == "glorot":
        fan_out = spec.shape[-1]
        std = math.sqrt(2.0 / max(spec.fan_in + fan_out, 2))
    else:
        raise ValueError(f"unknown init {spec.init!r}")
    return std * jax.random.normal(key, spec.shape, jnp.float32)


def init_params(seed: jnp.ndarray, specs: Sequence[ParamSpec]) -> dict:
    """Initialize the full tree; per-tensor keys are folded from the seed."""
    key = jax.random.PRNGKey(seed)
    return {
        s.name: init_param(jax.random.fold_in(key, i), s)
        for i, s in enumerate(specs)
    }


# ---------------------------------------------------------------------------
# layers (NHWC activations, HWIO conv kernels)
# ---------------------------------------------------------------------------


def conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None,
           stride: int = 1, padding: str = "SAME") -> jnp.ndarray:
    """2-D convolution, NHWC x HWIO -> NHWC."""
    y = lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x @ w + b


def max_pool(x: jnp.ndarray, window: int = 2, stride: int = 2) -> jnp.ndarray:
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID",
    )


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def channel_affine(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Per-channel affine (the BN substitution — see DESIGN.md §3)."""
    return x * scale + bias


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.relu(x)


# ---------------------------------------------------------------------------
# loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy over the batch. labels: int32 [B]."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def correct_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.int32))


# ---------------------------------------------------------------------------
# spec helpers
# ---------------------------------------------------------------------------


def conv_spec(name: str, k: int, cin: int, cout: int) -> list[ParamSpec]:
    fan = k * k * cin
    return [
        ParamSpec(f"{name}.w", (k, k, cin, cout), "he", fan),
        ParamSpec(f"{name}.b", (cout,), "zeros"),
    ]


def dense_spec(name: str, din: int, dout: int, init: str = "he") -> list[ParamSpec]:
    return [
        ParamSpec(f"{name}.w", (din, dout), init, din),
        ParamSpec(f"{name}.b", (dout,), "zeros"),
    ]


def affine_spec(name: str, c: int) -> list[ParamSpec]:
    return [
        ParamSpec(f"{name}.scale", (c,), "ones"),
        ParamSpec(f"{name}.bias", (c,), "zeros"),
    ]
