"""Paper benchmark #1: the "Vanilla CNN" of McMahan et al. [1] on
Fashion-MNIST — conv5x5 -> pool -> conv5x5 -> pool -> fc -> fc.

Channel/fc widths are configurable: the paper uses (32, 64, 512) ≈ 1.66M
params; the CPU-scaled default is (8, 16, 64) ≈ 54k params, which keeps the
descending-range dynamics (what the policy consumes) intact while making
hundreds of federated rounds tractable on the CPU PJRT backend.
"""

from __future__ import annotations

from . import common as c


def build(cfg: dict) -> c.ModelDef:
    input_shape = tuple(cfg.get("input_shape", (28, 28, 1)))
    classes = int(cfg.get("classes", 10))
    c1 = int(cfg.get("conv1", 8))
    c2 = int(cfg.get("conv2", 16))
    fc = int(cfg.get("fc", 64))
    h, w, cin = input_shape
    # two SAME conv + 2x2 pool stages
    fh, fw = h // 4, w // 4
    flat = fh * fw * c2

    specs = tuple(
        c.conv_spec("conv1", 5, cin, c1)
        + c.conv_spec("conv2", 5, c1, c2)
        + c.dense_spec("fc1", flat, fc)
        + c.dense_spec("fc2", fc, classes, init="glorot")
    )

    def apply(params: dict, x):
        b = x.shape[0]
        h1 = c.relu(c.conv2d(x, params["conv1.w"], params["conv1.b"]))
        h1 = c.max_pool(h1)
        h2 = c.relu(c.conv2d(h1, params["conv2.w"], params["conv2.b"]))
        h2 = c.max_pool(h2)
        hf = h2.reshape(b, -1)
        hf = c.relu(c.dense(hf, params["fc1.w"], params["fc1.b"]))
        return c.dense(hf, params["fc2.w"], params["fc2.b"])

    return c.ModelDef("vanilla_cnn", specs, apply, input_shape, classes)
