"""Paper benchmark #2: CNN with 4 convolution layers + 3 fully-connected
layers on CIFAR-10.

The paper gives only the layer counts; we use 3x3 convs with pooling after
conv2/conv3/conv4 (32 -> 16 -> 8 -> 4 spatial) and an fc head, all widths
configurable.  Default widths are CPU-scaled (≈93k params); the per-layer
count (4 conv + 3 fc = 14 quantization segments with biases) matches the
paper's granularity for the per-layer range curves (Fig. 1b).
"""

from __future__ import annotations

from . import common as c


def build(cfg: dict) -> c.ModelDef:
    input_shape = tuple(cfg.get("input_shape", (32, 32, 3)))
    classes = int(cfg.get("classes", 10))
    c1 = int(cfg.get("conv1", 16))
    c2 = int(cfg.get("conv2", 16))
    c3 = int(cfg.get("conv3", 32))
    c4 = int(cfg.get("conv4", 32))
    f1 = int(cfg.get("fc1", 128))
    f2 = int(cfg.get("fc2", 64))
    h, w, cin = input_shape
    fh, fw = h // 8, w // 8  # three 2x2 pools
    flat = fh * fw * c4

    specs = tuple(
        c.conv_spec("conv1", 3, cin, c1)
        + c.conv_spec("conv2", 3, c1, c2)
        + c.conv_spec("conv3", 3, c2, c3)
        + c.conv_spec("conv4", 3, c3, c4)
        + c.dense_spec("fc1", flat, f1)
        + c.dense_spec("fc2", f1, f2)
        + c.dense_spec("fc3", f2, classes, init="glorot")
    )

    def apply(params: dict, x):
        b = x.shape[0]
        h1 = c.relu(c.conv2d(x, params["conv1.w"], params["conv1.b"]))
        h2 = c.relu(c.conv2d(h1, params["conv2.w"], params["conv2.b"]))
        h2 = c.max_pool(h2)
        h3 = c.relu(c.conv2d(h2, params["conv3.w"], params["conv3.b"]))
        h3 = c.max_pool(h3)
        h4 = c.relu(c.conv2d(h3, params["conv4.w"], params["conv4.b"]))
        h4 = c.max_pool(h4)
        hf = h4.reshape(b, -1)
        hf = c.relu(c.dense(hf, params["fc1.w"], params["fc1.b"]))
        hf = c.relu(c.dense(hf, params["fc2.w"], params["fc2.b"]))
        return c.dense(hf, params["fc3.w"], params["fc3.b"])

    return c.ModelDef("cnn4", specs, apply, input_shape, classes)
