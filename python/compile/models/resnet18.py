"""Paper benchmark #3: ResNet-18 on CIFAR-10.

Faithful ResNet-18 topology (BasicBlock x [2, 2, 2, 2], stride-2 stage
transitions with 1x1 projection shortcuts, global average pool + fc), with
two documented substitutions (DESIGN.md §3):

  * batch-norm -> per-channel affine.  Aggregating BN running statistics in
    FL is a research topic orthogonal to quantization policy; an affine
    keeps the parameter-segment structure (scale/bias per conv) so the
    per-layer range curves retain ResNet-18's segment count.
  * configurable base width (default 8 vs the canonical 64) so that the
    ~25-round federated runs of Fig. 4 complete on the CPU backend.  The
    canonical width is one config key away (``base: 64``).
"""

from __future__ import annotations

from . import common as c


def _block_specs(name: str, cin: int, cout: int, stride: int) -> list[c.ParamSpec]:
    # aff2.scale starts small (soft Fixup) so residual branches neither
    # explode (he-init would) nor die (zero-init starves the gradient path
    # at the narrow CPU-scale widths) — 0.25 trains stably at lr 0.1.
    specs = (
        c.conv_spec(f"{name}.conv1", 3, cin, cout)
        + c.affine_spec(f"{name}.aff1", cout)
        + c.conv_spec(f"{name}.conv2", 3, cout, cout)
        + [
            c.ParamSpec(f"{name}.aff2.scale", (cout,), "const:0.25"),
            c.ParamSpec(f"{name}.aff2.bias", (cout,), "zeros"),
        ]
    )
    if stride != 1 or cin != cout:
        specs += c.conv_spec(f"{name}.proj", 1, cin, cout)
    return specs


def _apply_block(params: dict, name: str, x, cin: int, cout: int, stride: int):
    h = c.conv2d(x, params[f"{name}.conv1.w"], params[f"{name}.conv1.b"],
                 stride=stride)
    h = c.channel_affine(h, params[f"{name}.aff1.scale"], params[f"{name}.aff1.bias"])
    h = c.relu(h)
    h = c.conv2d(h, params[f"{name}.conv2.w"], params[f"{name}.conv2.b"])
    h = c.channel_affine(h, params[f"{name}.aff2.scale"], params[f"{name}.aff2.bias"])
    if stride != 1 or cin != cout:
        x = c.conv2d(x, params[f"{name}.proj.w"], params[f"{name}.proj.b"],
                     stride=stride)
    return c.relu(h + x)


def build(cfg: dict) -> c.ModelDef:
    input_shape = tuple(cfg.get("input_shape", (32, 32, 3)))
    classes = int(cfg.get("classes", 10))
    base = int(cfg.get("base", 8))
    h, w, cin = input_shape

    widths = [base, base * 2, base * 4, base * 8]
    layers = [2, 2, 2, 2]  # ResNet-18

    specs: list[c.ParamSpec] = []
    specs += c.conv_spec("stem", 3, cin, base)
    specs += c.affine_spec("stem.aff", base)
    plan: list[tuple[str, int, int, int]] = []  # (name, cin, cout, stride)
    prev = base
    for stage, (wd, reps) in enumerate(zip(widths, layers)):
        for r in range(reps):
            stride = 2 if (stage > 0 and r == 0) else 1
            name = f"s{stage}b{r}"
            plan.append((name, prev, wd, stride))
            specs += _block_specs(name, prev, wd, stride)
            prev = wd
    specs += c.dense_spec("fc", prev, classes, init="glorot")

    def apply(params: dict, x):
        hh = c.conv2d(x, params["stem.w"], params["stem.b"])
        hh = c.channel_affine(hh, params["stem.aff.scale"], params["stem.aff.bias"])
        hh = c.relu(hh)
        for name, ci, co, st in plan:
            hh = _apply_block(params, name, hh, ci, co, st)
        hh = c.global_avg_pool(hh)
        return c.dense(hh, params["fc.w"], params["fc.b"])

    return c.ModelDef("resnet18", tuple(specs), apply, input_shape, classes)
