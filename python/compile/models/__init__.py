"""L2 model zoo: the paper's three benchmarks plus an MLP for quickstart.

Every model exposes the same functional contract (see common.ModelDef) so
the flat-parameter machinery in compile/model.py and the Rust coordinator
treat all of them uniformly.
"""

from . import cnn4, mlp, resnet18, vanilla_cnn
from .common import ModelDef

_BUILDERS = {
    "mlp": mlp.build,
    "vanilla_cnn": vanilla_cnn.build,
    "cnn4": cnn4.build,
    "resnet18": resnet18.build,
}


def build_model(name: str, cfg: dict) -> ModelDef:
    """Construct a ModelDef by registry name."""
    if name not in _BUILDERS:
        raise KeyError(f"unknown model {name!r}; have {sorted(_BUILDERS)}")
    return _BUILDERS[name](cfg)


def model_names() -> list[str]:
    return sorted(_BUILDERS)
