"""Two-layer MLP — not a paper benchmark; used for quickstart and fast tests.

Small enough (≈100k params at default width) that the full FL loop runs in
seconds on CPU, which makes it the workhorse for integration tests and the
quickstart example.
"""

from __future__ import annotations

import math

from . import common as c


def build(cfg: dict) -> c.ModelDef:
    input_shape = tuple(cfg.get("input_shape", (28, 28, 1)))
    classes = int(cfg.get("classes", 10))
    hidden = int(cfg.get("hidden", 128))
    din = math.prod(input_shape)

    specs = tuple(
        c.dense_spec("fc1", din, hidden)
        + c.dense_spec("fc2", hidden, classes, init="glorot")
    )

    def apply(params: dict, x):
        b = x.shape[0]
        h = x.reshape(b, -1)
        h = c.relu(c.dense(h, params["fc1.w"], params["fc1.b"]))
        return c.dense(h, params["fc2.w"], params["fc2.b"])

    return c.ModelDef("mlp", specs, apply, input_shape, classes)
