"""Tiled, segment-aligned layout for the flat model-update vector.

The FedDQ wire path operates on a model update ``delta in R^d`` that is
logically partitioned into L *segments* (one per parameter tensor — the
paper quantizes per-layer, Fig. 1b / Fig. 5).  The Pallas kernels process
the vector as a 1-D grid of fixed-size tiles; to keep every kernel body
branch-free we pad each segment up to a tile multiple so that **every tile
belongs to exactly one segment**.  Per-segment scalars (min, 1/step, max
code) are then expanded to cheap per-tile arrays on the host side of the
trace, and each tile's BlockSpec picks out its own scalar.

On a real TPU this layout is exactly the VMEM-friendly schedule: tiles are
sized to a multiple of the (8, 128) vreg footprint, the 1-D grid gives the
Mosaic pipeline free double-buffering, and the per-tile scalars ride along
as tiny SMEM operands.  See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# 8 sublanes x 128 lanes x 1 = one f32 vreg-aligned chunk; 1024 f32 = 4 KiB.
# A tile is deliberately small in interpret mode (cheap numpy ops); the TPU
# estimate in DESIGN.md uses 64 Ki-element tiles (256 KiB) instead — the
# layout code is parametric in TILE so both are one constant away.
TILE = 1024


@dataclasses.dataclass(frozen=True)
class PaddedLayout:
    """Static description of the segment-aligned padded layout."""

    seg_sizes: tuple[int, ...]        # original element count per segment
    seg_offsets: tuple[int, ...]      # offsets into the unpadded vector
    seg_tiles: tuple[int, ...]        # tiles occupied by each segment
    pad_offsets: tuple[int, ...]      # offsets into the padded vector
    tile_seg_ids: np.ndarray          # [T] segment id of each tile
    tile_valid: np.ndarray            # [T] number of valid lanes in each tile
    d: int                            # unpadded length
    padded: int                       # padded length (= T * TILE)
    tiles: int                        # T

    @property
    def num_segments(self) -> int:
        return len(self.seg_sizes)


def make_layout(seg_sizes: Sequence[int], tile: int = TILE) -> PaddedLayout:
    """Build the padded layout for the given per-segment sizes."""
    if not seg_sizes:
        raise ValueError("need at least one segment")
    if any(s <= 0 for s in seg_sizes):
        raise ValueError(f"segment sizes must be positive, got {seg_sizes}")
    seg_offsets, pad_offsets, seg_tiles = [], [], []
    tile_seg_ids, tile_valid = [], []
    off = 0
    poff = 0
    for sid, size in enumerate(seg_sizes):
        ntiles = -(-size // tile)  # ceil
        seg_offsets.append(off)
        pad_offsets.append(poff)
        seg_tiles.append(ntiles)
        for t in range(ntiles):
            tile_seg_ids.append(sid)
            lo = t * tile
            tile_valid.append(min(size - lo, tile))
        off += size
        poff += ntiles * tile
    return PaddedLayout(
        seg_sizes=tuple(seg_sizes),
        seg_offsets=tuple(seg_offsets),
        seg_tiles=tuple(seg_tiles),
        pad_offsets=tuple(pad_offsets),
        tile_seg_ids=np.asarray(tile_seg_ids, dtype=np.int32),
        tile_valid=np.asarray(tile_valid, dtype=np.int32),
        d=off,
        padded=poff,
        tiles=len(tile_seg_ids),
    )


def pad(layout: PaddedLayout, x: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Scatter the unpadded vector into the segment-aligned padded layout.

    Pure static slicing, so it traces to a fixed concat of pads — XLA fuses
    this into the surrounding computation (verified in the L2 perf pass).
    """
    if x.shape != (layout.d,):
        raise ValueError(f"expected shape ({layout.d},), got {x.shape}")
    parts = []
    for sid, size in enumerate(layout.seg_sizes):
        o = layout.seg_offsets[sid]
        seg = x[o : o + size]
        padlen = layout.seg_tiles[sid] * tile - size
        if padlen:
            seg = jnp.pad(seg, (0, padlen))
        parts.append(seg)
    return jnp.concatenate(parts)


def unpad(layout: PaddedLayout, xp: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Gather the unpadded vector back out of the padded layout."""
    if xp.shape != (layout.padded,):
        raise ValueError(f"expected shape ({layout.padded},), got {xp.shape}")
    parts = []
    for sid, size in enumerate(layout.seg_sizes):
        po = layout.pad_offsets[sid]
        parts.append(xp[po : po + size])
    return jnp.concatenate(parts)


def expand_per_tile(layout: PaddedLayout, per_seg: jnp.ndarray) -> jnp.ndarray:
    """Expand a [L] (or [..., L]) per-segment array to per-tile [..., T]."""
    ids = jnp.asarray(layout.tile_seg_ids)
    return jnp.take(per_seg, ids, axis=-1)
