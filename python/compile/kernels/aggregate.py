"""L1 Pallas kernel: server-side fused dequantize + weighted aggregation.

The server receives per-client code vectors plus per-client per-segment
(min, step) pairs and reconstructs the aggregated global update
(paper Eq. 4)::

    delta_j = sum_i  w_i * ( codes_ij * step_il(j) + min_il(j) )

in one pass — the dequantized per-client updates are never materialized.
The grid is 1-D over segment-aligned tiles; each tile reads an [n, tile]
block of codes and the [n, 1] per-tile scalar columns.

The fp32 (unquantized) path reuses the same kernel with
``codes = delta, step = 1, min = 0`` so the coordinator has a single
aggregation code path regardless of policy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import layout as L


def _agg_kernel(codes_ref, step_ref, min_ref, w_ref, o_ref):
    codes = codes_ref[...]        # [n, tile]
    vals = codes * step_ref[...] + min_ref[...]
    o_ref[...] = jnp.sum(w_ref[...] * vals, axis=0)


@functools.partial(jax.jit, static_argnames=("n", "tiles", "tile"))
def _aggregate_padded(codes_p, step_t, min_t, w, *, n: int, tiles: int, tile: int):
    return pl.pallas_call(
        _agg_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((n, tile), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tiles * tile,), jnp.float32),
        interpret=True,
    )(codes_p, step_t, min_t, w)


def dequant_aggregate(
    lay: L.PaddedLayout,
    codes: jnp.ndarray,
    mins: jnp.ndarray,
    steps: jnp.ndarray,
    weights: jnp.ndarray,
    tile: int = L.TILE,
) -> jnp.ndarray:
    """Fused dequantize + weighted sum across clients.

    Args:
      lay:     segment layout (shared by all clients).
      codes:   f32[n, d] integer-valued codes per client.
      mins:    f32[n, L] per-client per-segment minimum.
      steps:   f32[n, L] per-client per-segment step (``range / s``).
      weights: f32[n] aggregation weights ``p_i`` (paper Eq. 1/4).

    Returns:
      f32[d] aggregated global update.
    """
    n = codes.shape[0]
    codes_p = jax.vmap(lambda c: L.pad(lay, c, tile))(codes)
    step_t = L.expand_per_tile(lay, steps)   # [n, T]
    min_t = L.expand_per_tile(lay, mins)     # [n, T]
    out = _aggregate_padded(
        codes_p, step_t, min_t, weights[:, None],
        n=n, tiles=lay.tiles, tile=tile,
    )
    return L.unpad(lay, out, tile)
