"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package has a reference implementation here written
with plain segment slicing and jnp reductions (no tiling, no padding, no
Pallas).  pytest + hypothesis compare kernel-vs-ref across shapes, segment
partitions, levels and seeds (python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layout as L


def segment_ranges_ref(
    lay: L.PaddedLayout, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-segment (min, range) by direct slicing."""
    mins, ranges = [], []
    for sid, size in enumerate(lay.seg_sizes):
        o = lay.seg_offsets[sid]
        seg = x[o : o + size]
        lo = jnp.min(seg)
        hi = jnp.max(seg)
        mins.append(lo)
        ranges.append(hi - lo)
    return jnp.stack(mins), jnp.stack(ranges)


def stochastic_quantize_ref(
    lay: L.PaddedLayout,
    x: jnp.ndarray,
    mins: jnp.ndarray,
    sinv: jnp.ndarray,
    maxcode: jnp.ndarray,
    uniforms: jnp.ndarray,
) -> jnp.ndarray:
    """Elementwise stochastic rounding with per-segment params.

    ``uniforms`` is in the *padded* layout (that is the executable's input
    contract); the reference gathers the lanes that correspond to real
    elements so kernel and ref consume identical randomness.
    """
    parts = []
    for sid, size in enumerate(lay.seg_sizes):
        o = lay.seg_offsets[sid]
        po = lay.pad_offsets[sid]
        seg = x[o : o + size]
        u = uniforms[po : po + size]
        y = (seg - mins[sid]) * sinv[sid] + u
        parts.append(jnp.clip(jnp.floor(y), 0.0, maxcode[sid]))
    return jnp.concatenate(parts)


def dequant_aggregate_ref(
    lay: L.PaddedLayout,
    codes: jnp.ndarray,
    mins: jnp.ndarray,
    steps: jnp.ndarray,
    weights: jnp.ndarray,
) -> jnp.ndarray:
    """Weighted sum of per-client dequantized updates, segment by segment."""
    n = codes.shape[0]
    out = jnp.zeros(lay.d, dtype=jnp.float32)
    for i in range(n):
        parts = []
        for sid, size in enumerate(lay.seg_sizes):
            o = lay.seg_offsets[sid]
            seg = codes[i, o : o + size]
            parts.append(seg * steps[i, sid] + mins[i, sid])
        out = out + weights[i] * jnp.concatenate(parts)
    return out
