"""L1 Pallas kernel: per-segment (per-layer) min/max of the model update.

FedDQ\'s policy input is the *range* of each client\'s model update
(paper Eq. 7/10, Fig. 1b).  This kernel computes per-tile min/max in a
single pass over the segment-aligned padded vector (1-D grid of tiles, a
[2, 1] min/max column written per tile); the tiny [T] tile results are
then combined into per-segment values with *static* slice reductions
(tiles are contiguous per segment by construction — do NOT use
jax.ops.segment_min here, its scatter lowering is not supported by the
old xla_extension runtime on the Rust side).

Padding lanes are masked with an iota-vs-valid-count compare so padded
zeros can never contaminate a segment whose true range excludes zero.
The [T] valid-count table is an HLO constant; see aot.py on
``print_large_constants``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import layout as L


def _minmax_kernel(x_ref, valid_ref, o_ref, *, tile: int):
    x = x_ref[...]
    valid = valid_ref[0]
    idx = lax.iota(jnp.int32, tile)
    mask = idx < valid
    o_ref[0, 0] = jnp.min(jnp.where(mask, x, jnp.inf))
    o_ref[1, 0] = jnp.max(jnp.where(mask, x, -jnp.inf))


@functools.partial(jax.jit, static_argnames=("tiles", "tile"))
def _tile_minmax(xp, valid_t, *, tiles: int, tile: int):
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, tile=tile),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((2, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, tiles), jnp.float32),
        interpret=True,
    )(xp, valid_t)
    return out[0], out[1]


def segment_ranges(
    lay: L.PaddedLayout, x: jnp.ndarray, tile: int = L.TILE
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-segment (min, range) of the unpadded update ``x [d]``.

    Returns ``(mins [L], ranges [L])`` with ``range_l = max_l - min_l >= 0``.
    """
    xp = L.pad(lay, x, tile)
    tmin, tmax = _tile_minmax(
        xp, jnp.asarray(lay.tile_valid), tiles=lay.tiles, tile=tile
    )
    mins, maxs = [], []
    t0 = 0
    for nt in lay.seg_tiles:
        mins.append(jnp.min(tmin[t0 : t0 + nt]))
        maxs.append(jnp.max(tmax[t0 : t0 + nt]))
        t0 += nt
    mins = jnp.stack(mins)
    maxs = jnp.stack(maxs)
    return mins, maxs - mins
